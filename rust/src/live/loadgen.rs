//! Closed-loop concurrent load generator for the live engine.
//!
//! Reuses the `workload::*` generators (the same IOR/HPIO/MPI-Tile-IO
//! patterns the simulator evaluates): the workload's processes are dealt
//! round-robin onto `clients` OS threads, and each thread interleaves its
//! processes one request at a time — request `i+1` of a process is issued
//! only after request `i` returned (closed loop), which is what gives the
//! server-side streams the paper's mixed composition. Every request's
//! wall-clock latency lands in a per-thread [`LatencyHistogram`]; the
//! histograms merge into the final [`LiveReport`].
//!
//! `after_app` dependencies are honored: a process gated on another app
//! starts only after every process of that app has completed, plus the
//! workload's compute gap (Fig 14's sequential two-app scenarios run in
//! the paper's order). Gating is cross-thread — an [`AppGate`] tracks
//! per-app completion and wakes waiters when a predecessor finishes.

use std::collections::HashMap;
use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::live::engine::LiveEngine;
use crate::live::payload;
use crate::live::shard::ShardStats;
use crate::obs::{Counters, Snapshotter, StageSet};
use crate::server::metrics::LatencyHistogram;
use crate::util::threadpool::scoped_map;
use crate::workload::{ProcessWorkload, Workload};

/// Fallback poll interval while parked on a gate (the condvar wake on
/// predecessor completion is the fast path; this bounds gap cool-downs).
const GATE_POLL: Duration = Duration::from_millis(5);

/// Result of one live run: wall-clock timings, latency distribution, and
/// the per-shard counters.
#[derive(Clone, Debug)]
pub struct LiveReport {
    pub workload: String,
    /// wall time until the last request was acknowledged
    pub ingest_us: u64,
    /// wall time including the final drain to HDD
    pub total_us: u64,
    pub total_bytes: u64,
    pub requests: u64,
    /// requests the engine rejected with a typed error (shutdown or a
    /// permanent device fault) instead of acknowledging — excluded from
    /// the latency histogram, never counted as delivered
    pub rejected: u64,
    pub latency: LatencyHistogram,
    pub shards: Vec<ShardStats>,
    /// per-stage ack-latency attribution, merged across shards
    pub stages: StageSet,
}

impl LiveReport {
    /// Application-visible ingest throughput, MB/s.
    pub fn throughput_mbps(&self) -> f64 {
        if self.ingest_us == 0 {
            return 0.0;
        }
        self.total_bytes as f64 / self.ingest_us as f64
    }

    /// Throughput including the drain tail, MB/s.
    pub fn drained_throughput_mbps(&self) -> f64 {
        if self.total_us == 0 {
            return 0.0;
        }
        self.total_bytes as f64 / self.total_us as f64
    }

    /// Fraction of ingested bytes that went through the SSD buffer.
    pub fn ssd_ratio(&self) -> f64 {
        crate::live::shard::ssd_ratio(&self.shards)
    }

    /// Device syncs issued across all shards (SSD + HDD).
    pub fn syncs(&self) -> u64 {
        self.shards.iter().map(|s| s.syncs).sum()
    }

    /// Aggregate group-commit batching factor: durability barriers
    /// requested per device sync actually issued (≈1 without group
    /// commit, >1 when concurrent publishers shared barriers).
    pub fn writes_per_sync(&self) -> f64 {
        let syncs = self.syncs();
        if syncs == 0 {
            0.0
        } else {
            self.shards.iter().map(|s| s.sync_barriers).sum::<u64>() as f64 / syncs as f64
        }
    }

    /// Peak achieved submission-queue depth across all device queues.
    pub fn io_depth_high_water(&self) -> u64 {
        self.shards.iter().map(|s| s.io_depth_high_water).max().unwrap_or(0)
    }

    /// Mean achieved queue depth at enqueue, request-weighted across
    /// shards.
    pub fn io_mean_depth(&self) -> f64 {
        let reqs: u64 = self.shards.iter().map(|s| s.io_reqs).sum();
        if reqs == 0 {
            return 0.0;
        }
        self.shards.iter().map(|s| s.io_mean_depth * s.io_reqs as f64).sum::<f64>()
            / reqs as f64
    }

    /// Device writes saved by byte-adjacent coalescing in the I/O
    /// queues (requests enqueued minus device writes issued).
    pub fn io_coalesced(&self) -> u64 {
        let reqs: u64 = self.shards.iter().map(|s| s.io_reqs).sum();
        let dev: u64 = self.shards.iter().map(|s| s.io_device_writes).sum();
        reqs.saturating_sub(dev)
    }

    /// Device-level retries absorbed below the ack across all shards.
    pub fn io_retries(&self) -> u64 {
        self.shards.iter().map(|s| s.io_retries).sum()
    }

    /// Transient device faults observed (and re-driven) across shards.
    pub fn transient_faults(&self) -> u64 {
        self.shards.iter().map(|s| s.transient_faults).sum()
    }

    /// Shards flying degraded (SSD written off, direct-to-HDD routing).
    pub fn degraded_shards(&self) -> u64 {
        self.shards.iter().filter(|s| s.degraded).count() as u64
    }

    pub fn summary(&self) -> String {
        let mut line = format!(
            "{:<34} {:>8.2} MB/s ingest ({:>7.2} MB/s drained)  ssd {:>5.1}%  \
             {} syncs ({:.1} w/s)  qd {:.1}/{}  lat {}",
            self.workload,
            self.throughput_mbps(),
            self.drained_throughput_mbps(),
            self.ssd_ratio() * 100.0,
            self.syncs(),
            self.writes_per_sync(),
            self.io_mean_depth(),
            self.io_depth_high_water(),
            self.latency.summary(),
        );
        if self.io_retries() > 0 || self.rejected > 0 || self.degraded_shards() > 0 {
            line.push_str(&format!(
                "  faults: {} retries, {} rejected, {} degraded",
                self.io_retries(),
                self.rejected,
                self.degraded_shards(),
            ));
        }
        line
    }

    /// Multi-line per-stage latency decomposition (p50/p95/p99 per
    /// pipeline stage plus the dominant ack stage).
    pub fn stage_summary(&self) -> String {
        self.stages.summary()
    }
}

/// How to emit periodic telemetry snapshots during a run: every
/// `interval`, one JSON line (throughput, writes/sync, blocked-wait
/// delta, flusher state, SSD occupancy) is written to `out`.
pub struct SnapshotOptions {
    pub interval: Duration,
    pub out: Box<dyn Write + Send>,
}

/// Outcome of asking the gate whether a dependent process may start.
enum GateCheck {
    Ready,
    /// predecessor app still running: wait for its completion signal
    Waiting,
    /// predecessor done, compute gap still cooling down
    Cooling(Duration),
}

/// Tracks per-app completion across client threads so `after_app`
/// processes start only after their predecessor finished plus the gap.
struct AppGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    /// processes still running, per app (absent = app completed)
    remaining: HashMap<u16, usize>,
    /// wall-clock completion instant, per completed app
    done_at: HashMap<u16, Instant>,
}

impl AppGate {
    fn new(workload: &Workload) -> Self {
        let mut remaining: HashMap<u16, usize> = HashMap::new();
        for p in &workload.processes {
            *remaining.entry(p.app).or_insert(0) += 1;
        }
        Self {
            state: Mutex::new(GateState { remaining, done_at: HashMap::new() }),
            cv: Condvar::new(),
        }
    }

    fn check(&self, dep: u16, gap_us: u64) -> GateCheck {
        let st = self.state.lock().unwrap();
        match st.done_at.get(&dep) {
            Some(&t) => {
                let gap = Duration::from_micros(gap_us);
                let waited = t.elapsed();
                if waited >= gap {
                    GateCheck::Ready
                } else {
                    GateCheck::Cooling(gap - waited)
                }
            }
            // a dependency on an app with no processes can never fire:
            // treat it as satisfied rather than deadlock
            None if !st.remaining.contains_key(&dep) => GateCheck::Ready,
            None => GateCheck::Waiting,
        }
    }

    /// One process of `app` completed all its requests.
    fn mark_done(&self, app: u16) {
        let mut st = self.state.lock().unwrap();
        if let Some(n) = st.remaining.get_mut(&app) {
            *n -= 1;
            if *n == 0 {
                st.remaining.remove(&app);
                st.done_at.insert(app, Instant::now());
                self.cv.notify_all();
            }
        }
    }

    /// Park until a completion signal arrives or `dur` elapses.
    fn park(&self, dur: Duration) {
        let st = self.state.lock().unwrap();
        let _ = self.cv.wait_timeout(st, dur).unwrap();
    }
}

/// Reject cyclic `after_app` graphs up front: a cycle can never make
/// progress and would park every client thread forever. (Self-deps are
/// ignored here and at the gate — they mean "start immediately".)
fn assert_acyclic(workload: &Workload) {
    let mut dep: HashMap<u16, u16> = HashMap::new();
    for p in &workload.processes {
        if let Some((d, _)) = p.after_app {
            if d != p.app {
                dep.insert(p.app, d);
            }
        }
    }
    for &start in dep.keys() {
        let mut cur = start;
        let mut hops = 0;
        while let Some(&d) = dep.get(&cur) {
            cur = d;
            hops += 1;
            assert!(hops <= dep.len(), "after_app dependency cycle involving app {start}");
        }
    }
}

/// Drive `workload` through `engine` from `clients` concurrent closed-loop
/// threads, then drain. The engine must be fresh (one run per engine).
pub fn run(engine: &LiveEngine, workload: &Workload, clients: usize) -> LiveReport {
    run_with(engine, workload, clients, false)
}

/// Like [`run`], with `versioned` payloads: every request's bytes are
/// stamped with its per-process write generation
/// ([`payload::write_gen`]), so rewrite-heavy workloads stay verifiable
/// via [`LiveEngine::verify_workload_versioned`] — including *which* copy
/// of a rewritten sector survived.
///
/// [`LiveEngine::verify_workload_versioned`]: crate::live::LiveEngine::verify_workload_versioned
pub fn run_with(
    engine: &LiveEngine,
    workload: &Workload,
    clients: usize,
    versioned: bool,
) -> LiveReport {
    run_reported(engine, workload, clients, versioned, None)
}

/// Like [`run_with`], optionally emitting periodic telemetry snapshots
/// while the run is in flight: a sampler thread snapshots the engine's
/// counters every `snapshots.interval` and writes one JSON line per tick
/// (plus a final tick at the end of the drain). The sampler only reads
/// engine stats — it never touches the data path.
pub fn run_reported(
    engine: &LiveEngine,
    workload: &Workload,
    clients: usize,
    versioned: bool,
    snapshots: Option<SnapshotOptions>,
) -> LiveReport {
    let Some(snap) = snapshots else {
        return run_inner(engine, workload, clients, versioned);
    };
    let stop = AtomicBool::new(false);
    let stop = &stop;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        let SnapshotOptions { interval, mut out } = snap;
        s.spawn(move || {
            let mut snapper = Snapshotter::new();
            // sleep in short chunks so the final tick lands promptly
            // once the run completes, regardless of the interval
            let chunk = interval.max(Duration::from_millis(1)).min(Duration::from_millis(10));
            loop {
                let mut slept = Duration::ZERO;
                // Acquire: pairs with the Release store below so the
                // snapshotter sees the run's final stats before exiting
                while slept < interval && !stop.load(Ordering::Acquire) {
                    std::thread::sleep(chunk);
                    slept += chunk;
                }
                let mut counters =
                    Counters::from_stats(&engine.stats(), engine.trace().dropped_events());
                // the holders gauge lives on the coordinator, not in
                // the per-shard stats
                counters.flush_token_holders = engine.flush_token_holders().len() as u64;
                let line = snapper.tick(counters, t0.elapsed());
                let _ = writeln!(out, "{line}");
                // the last line is always a fresh end-of-run snapshot
                // (Acquire: same pairing as the loop condition above)
                if stop.load(Ordering::Acquire) {
                    break;
                }
            }
        });
        let report = run_inner(engine, workload, clients, versioned);
        // Release: publishes the finished run's stats to the snapshotter
        // thread's Acquire loads before it takes the final snapshot
        stop.store(true, Ordering::Release);
        report
    })
}

fn run_inner(
    engine: &LiveEngine,
    workload: &Workload,
    clients: usize,
    versioned: bool,
) -> LiveReport {
    let clients = clients.max(1);
    assert_acyclic(workload);
    // deal processes round-robin onto client threads
    let mut groups: Vec<Vec<&ProcessWorkload>> = (0..clients).map(|_| Vec::new()).collect();
    for (i, proc) in workload.processes.iter().enumerate() {
        groups[i % clients].push(proc);
    }
    groups.retain(|g| !g.is_empty());
    let gate = AppGate::new(workload);

    let t0 = Instant::now();
    let jobs: Vec<_> = groups
        .into_iter()
        .map(|group| {
            let gate = &gate;
            move || {
                let mut hist = LatencyHistogram::new();
                let mut rejected = 0u64;
                let mut buf: Vec<u8> = Vec::new();
                // a process with no requests is complete by definition
                for proc in &group {
                    if proc.reqs.is_empty() {
                        gate.mark_done(proc.app);
                    }
                }
                // interleave this thread's processes one request at a time
                let mut cursors = vec![0usize; group.len()];
                loop {
                    let mut progressed = false;
                    let mut pending = false;
                    let mut cooldown: Option<Duration> = None;
                    for (proc, cursor) in group.iter().zip(cursors.iter_mut()) {
                        if *cursor >= proc.reqs.len() {
                            continue;
                        }
                        if *cursor == 0 {
                            // a self-dependency means "start immediately"
                            if let Some((dep, gap_us)) = proc.after_app.filter(|&(d, _)| d != proc.app) {
                                match gate.check(dep, gap_us) {
                                    GateCheck::Ready => {}
                                    GateCheck::Waiting => {
                                        pending = true;
                                        continue;
                                    }
                                    GateCheck::Cooling(d) => {
                                        pending = true;
                                        cooldown = Some(cooldown.map_or(d, |c| c.min(d)));
                                        continue;
                                    }
                                }
                            }
                        }
                        let req = proc.reqs[*cursor];
                        let gen = if versioned {
                            payload::write_gen(proc.proc_id, *cursor as u32)
                        } else {
                            0
                        };
                        *cursor += 1;
                        progressed = true;
                        // resize without clear: fill overwrites the whole
                        // buffer, and same-size requests skip the memset
                        buf.resize(req.bytes() as usize, 0);
                        payload::fill_gen(req.file, req.offset as i64, gen, &mut buf);
                        let start = Instant::now();
                        // a rejected request is not acknowledged: count
                        // it, keep its latency out of the histogram, and
                        // press on — degraded engines keep accepting
                        match engine.submit(req, &buf) {
                            Ok(()) => hist.record(start.elapsed().as_micros() as u64),
                            Err(_) => rejected += 1,
                        }
                        if *cursor == proc.reqs.len() {
                            gate.mark_done(proc.app);
                        }
                    }
                    if !progressed {
                        if !pending {
                            break;
                        }
                        // every runnable process is gated: park until a
                        // predecessor completes or a gap cools down
                        gate.park(cooldown.unwrap_or(GATE_POLL));
                    }
                }
                (hist, rejected)
            }
        })
        .collect();
    let results = scoped_map(jobs);
    let ingest_us = t0.elapsed().as_micros() as u64;

    engine.drain();
    let total_us = t0.elapsed().as_micros() as u64;

    let mut latency = LatencyHistogram::new();
    let mut rejected = 0u64;
    for (h, r) in &results {
        latency.merge(h);
        rejected += r;
    }
    LiveReport {
        workload: workload.name.clone(),
        ingest_us,
        total_us,
        total_bytes: workload.total_bytes(),
        requests: workload.total_requests() as u64,
        rejected,
        latency,
        shards: engine.stats(),
        stages: engine.stage_latency(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::live::backend::SyntheticLatency;
    use crate::live::engine::LiveConfig;
    use crate::server::config::SystemKind;
    use crate::types::DEFAULT_REQ_SECTORS;
    use crate::workload::ior::{ior, IorPattern};

    #[test]
    fn loadgen_runs_and_verifies_contiguous_ior() {
        let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(2).with_ssd_mib(32);
        let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
        // 32 MiB contiguous IOR over 4 procs
        let w = ior(0, IorPattern::SegmentedContiguous, 4, 65_536, DEFAULT_REQ_SECTORS, 5);
        let report = run(&engine, &w, 4);
        assert_eq!(report.requests, w.total_requests() as u64);
        assert_eq!(report.latency.count(), report.requests);
        assert_eq!(report.total_bytes, w.total_bytes());
        assert!(report.total_us >= report.ingest_us);
        let verify = engine.verify_workload(&w);
        assert!(verify.is_ok(), "{verify:?}");
        assert_eq!(verify.checked_bytes, w.total_bytes());
        engine.shutdown();
    }

    #[test]
    fn report_math_is_sane() {
        let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(1).with_ssd_mib(16);
        let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
        let w = ior(0, IorPattern::SegmentedContiguous, 2, 8_192, DEFAULT_REQ_SECTORS, 5);
        let report = run(&engine, &w, 2);
        assert!(report.throughput_mbps() > 0.0);
        assert!(report.throughput_mbps() >= report.drained_throughput_mbps());
        assert!(report.summary().contains("MB/s"));
        assert_eq!(report.rejected, 0, "a fault-free run rejects nothing");
        assert!(!report.summary().contains("faults:"), "quiet when nothing faulted");
        engine.shutdown();
    }

    #[test]
    fn after_app_gates_on_predecessor_completion_plus_gap() {
        let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(1).with_ssd_mib(16);
        let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
        let a = ior(0, IorPattern::SegmentedContiguous, 2, 2_048, DEFAULT_REQ_SECTORS, 5);
        let b = ior(0, IorPattern::SegmentedContiguous, 2, 2_048, DEFAULT_REQ_SECTORS, 6);
        // 80 ms compute gap: without gating the whole (tiny) run finishes
        // in well under that
        let w = Workload::sequential("seq", a, 80_000, b);
        let report = run(&engine, &w, 4);
        assert_eq!(report.requests, w.total_requests() as u64);
        assert!(
            report.ingest_us >= 80_000,
            "app B must wait out its predecessor plus the gap, got {} us",
            report.ingest_us
        );
        let verify = engine.verify_workload(&w);
        assert!(verify.is_ok(), "{verify:?}");
        engine.shutdown();
    }

    #[test]
    fn snapshot_reporter_emits_parseable_json_lines() {
        use std::sync::Arc;

        // Write target shared with the sampler thread so the test can
        // inspect what it wrote after the run.
        #[derive(Clone)]
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl Write for SharedBuf {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }

        let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(2).with_ssd_mib(16);
        let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
        let w = ior(0, IorPattern::SegmentedContiguous, 4, 16_384, DEFAULT_REQ_SECTORS, 5);
        let buf = SharedBuf(Arc::new(Mutex::new(Vec::new())));
        let report = run_reported(
            &engine,
            &w,
            4,
            false,
            Some(SnapshotOptions {
                interval: Duration::from_millis(5),
                out: Box::new(buf.clone()),
            }),
        );
        engine.shutdown();

        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(!lines.is_empty(), "sampler must emit at least the final tick");
        for line in &lines {
            let j = crate::util::json::Json::parse(line).unwrap_or_else(|e| {
                panic!("snapshot line must be valid JSON ({e:?}): {line}")
            });
            for key in [
                "seq",
                "mbps",
                "writes_per_sync",
                "ssd_occupancy_bytes",
                "superseded_at_flush",
                "flush_token_holders",
                "hot_defers",
            ] {
                assert!(j.get(key).is_some(), "snapshot line missing {key}: {line}");
            }
        }
        // the final tick is taken after ingest finished, so its running
        // total covers every submitted byte
        let last = crate::util::json::Json::parse(lines.last().unwrap()).unwrap();
        let bytes_in = last.get("bytes_in").and_then(|v| v.as_f64()).unwrap() as u64;
        assert_eq!(bytes_in, report.total_bytes);
    }

    #[test]
    fn report_carries_stage_decomposition() {
        use crate::obs::Stage;
        let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(1).with_ssd_mib(16);
        let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
        let w = ior(0, IorPattern::SegmentedContiguous, 2, 8_192, DEFAULT_REQ_SECTORS, 5);
        let report = run(&engine, &w, 2);
        engine.shutdown();
        assert_eq!(report.stages.get(Stage::Submit).count(), report.requests);
        assert_eq!(report.stages.get(Stage::Publish).count(), report.requests);
        // every acked write passed through the submission queue, so the
        // queue stages decompose alongside the device stages
        assert_eq!(report.stages.get(Stage::IoSubmit).count(), report.requests);
        assert_eq!(report.stages.get(Stage::QueueWait).count(), report.requests);
        assert!(report.io_depth_high_water() >= 1);
        assert!(report.stages.dominant_ack_stage().is_some());
        assert!(report.stage_summary().contains("dominant ack stage"));
    }

    #[test]
    fn gated_process_on_the_same_thread_does_not_deadlock() {
        // 1 client thread: the gated process shares its thread with the
        // predecessor, so the interleave loop must keep making progress
        // past the parked process
        let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(1).with_ssd_mib(16);
        let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
        let a = ior(0, IorPattern::SegmentedContiguous, 1, 1_024, DEFAULT_REQ_SECTORS, 5);
        let b = ior(0, IorPattern::SegmentedContiguous, 1, 1_024, DEFAULT_REQ_SECTORS, 6);
        let w = Workload::sequential("seq-1thread", a, 1_000, b);
        let report = run(&engine, &w, 1);
        assert_eq!(report.requests, w.total_requests() as u64);
        let verify = engine.verify_workload(&w);
        assert!(verify.is_ok(), "{verify:?}");
        engine.shutdown();
    }
}
