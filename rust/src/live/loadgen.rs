//! Closed-loop concurrent load generator for the live engine.
//!
//! Reuses the `workload::*` generators (the same IOR/HPIO/MPI-Tile-IO
//! patterns the simulator evaluates): the workload's processes are dealt
//! round-robin onto `clients` OS threads, and each thread interleaves its
//! processes one request at a time — request `i+1` of a process is issued
//! only after request `i` returned (closed loop), which is what gives the
//! server-side streams the paper's mixed composition. Every request's
//! wall-clock latency lands in a per-thread [`LatencyHistogram`]; the
//! histograms merge into the final [`LiveReport`].
//!
//! Limitation: `after_app` dependencies (sequential two-app workloads) are
//! treated as start-immediately; use concurrent workloads for live runs.

use std::time::Instant;

use crate::live::engine::LiveEngine;
use crate::live::payload;
use crate::live::shard::ShardStats;
use crate::server::metrics::LatencyHistogram;
use crate::util::threadpool::scoped_map;
use crate::workload::{ProcessWorkload, Workload};

/// Result of one live run: wall-clock timings, latency distribution, and
/// the per-shard counters.
#[derive(Clone, Debug)]
pub struct LiveReport {
    pub workload: String,
    /// wall time until the last request was acknowledged
    pub ingest_us: u64,
    /// wall time including the final drain to HDD
    pub total_us: u64,
    pub total_bytes: u64,
    pub requests: u64,
    pub latency: LatencyHistogram,
    pub shards: Vec<ShardStats>,
}

impl LiveReport {
    /// Application-visible ingest throughput, MB/s.
    pub fn throughput_mbps(&self) -> f64 {
        if self.ingest_us == 0 {
            return 0.0;
        }
        self.total_bytes as f64 / self.ingest_us as f64
    }

    /// Throughput including the drain tail, MB/s.
    pub fn drained_throughput_mbps(&self) -> f64 {
        if self.total_us == 0 {
            return 0.0;
        }
        self.total_bytes as f64 / self.total_us as f64
    }

    /// Fraction of ingested bytes that went through the SSD buffer.
    pub fn ssd_ratio(&self) -> f64 {
        crate::live::shard::ssd_ratio(&self.shards)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<34} {:>8.2} MB/s ingest ({:>7.2} MB/s drained)  ssd {:>5.1}%  lat {}",
            self.workload,
            self.throughput_mbps(),
            self.drained_throughput_mbps(),
            self.ssd_ratio() * 100.0,
            self.latency.summary(),
        )
    }
}

/// Drive `workload` through `engine` from `clients` concurrent closed-loop
/// threads, then drain. The engine must be fresh (one run per engine).
pub fn run(engine: &LiveEngine, workload: &Workload, clients: usize) -> LiveReport {
    let clients = clients.max(1);
    // deal processes round-robin onto client threads
    let mut groups: Vec<Vec<&ProcessWorkload>> = (0..clients).map(|_| Vec::new()).collect();
    for (i, proc) in workload.processes.iter().enumerate() {
        groups[i % clients].push(proc);
    }
    groups.retain(|g| !g.is_empty());

    let t0 = Instant::now();
    let jobs: Vec<_> = groups
        .into_iter()
        .map(|group| {
            move || {
                let mut hist = LatencyHistogram::new();
                let mut buf: Vec<u8> = Vec::new();
                // interleave this thread's processes one request at a time
                let mut cursors = vec![0usize; group.len()];
                loop {
                    let mut progressed = false;
                    for (proc, cursor) in group.iter().zip(cursors.iter_mut()) {
                        let Some(req) = proc.reqs.get(*cursor) else { continue };
                        *cursor += 1;
                        progressed = true;
                        // resize without clear: fill overwrites the whole
                        // buffer, and same-size requests skip the memset
                        buf.resize(req.bytes() as usize, 0);
                        payload::fill(req.file, req.offset as i64, &mut buf);
                        let start = Instant::now();
                        engine.submit(*req, &buf);
                        hist.record(start.elapsed().as_micros() as u64);
                    }
                    if !progressed {
                        break;
                    }
                }
                hist
            }
        })
        .collect();
    let hists = scoped_map(jobs);
    let ingest_us = t0.elapsed().as_micros() as u64;

    engine.drain();
    let total_us = t0.elapsed().as_micros() as u64;

    let mut latency = LatencyHistogram::new();
    for h in &hists {
        latency.merge(h);
    }
    LiveReport {
        workload: workload.name.clone(),
        ingest_us,
        total_us,
        total_bytes: workload.total_bytes(),
        requests: workload.total_requests() as u64,
        latency,
        shards: engine.stats(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::live::backend::SyntheticLatency;
    use crate::live::engine::LiveConfig;
    use crate::server::config::SystemKind;
    use crate::types::DEFAULT_REQ_SECTORS;
    use crate::workload::ior::{ior, IorPattern};

    #[test]
    fn loadgen_runs_and_verifies_contiguous_ior() {
        let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(2).with_ssd_mib(32);
        let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
        // 32 MiB contiguous IOR over 4 procs
        let w = ior(0, IorPattern::SegmentedContiguous, 4, 65_536, DEFAULT_REQ_SECTORS, 5);
        let report = run(&engine, &w, 4);
        assert_eq!(report.requests, w.total_requests() as u64);
        assert_eq!(report.latency.count(), report.requests);
        assert_eq!(report.total_bytes, w.total_bytes());
        assert!(report.total_us >= report.ingest_us);
        let verify = engine.verify_workload(&w);
        assert!(verify.is_ok(), "{verify:?}");
        assert_eq!(verify.checked_bytes, w.total_bytes());
        engine.shutdown();
    }

    #[test]
    fn report_math_is_sane() {
        let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(1).with_ssd_mib(16);
        let engine = LiveEngine::mem(&cfg, SyntheticLatency::ZERO, SyntheticLatency::ZERO);
        let w = ior(0, IorPattern::SegmentedContiguous, 2, 8_192, DEFAULT_REQ_SECTORS, 5);
        let report = run(&engine, &w, 2);
        assert!(report.throughput_mbps() > 0.0);
        assert!(report.throughput_mbps() >= report.drained_throughput_mbps());
        assert!(report.summary().contains("MB/s"));
        engine.shutdown();
    }
}
