//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from Rust. Python never runs here — the HLO text is
//! compiled once at startup by the in-process XLA CPU client.
//!
//! The execution path ([`xla_exec`]) needs the `xla` PJRT bindings and is
//! gated behind the `pjrt` cargo feature (the offline image ships no
//! crates.io mirror — see Cargo.toml). Artifact discovery and manifest
//! validation stay available in every build so tooling can report artifact
//! status, and `detector::hlo::default_backend` falls back to the native
//! detector mirror when PJRT is compiled out.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod xla_exec;

pub use artifacts::{ArtifactSet, Manifest};
#[cfg(feature = "pjrt")]
pub use xla_exec::{DetectorExec, Runtime, ThresholdExec};

/// Minimal error type for the artifact layer (`anyhow` is only available
/// under the `pjrt` feature, and the manifest loader must work without it).
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type RtResult<T> = std::result::Result<T, RuntimeError>;
