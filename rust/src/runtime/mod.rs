//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from Rust. Python never runs here — the HLO text is
//! compiled once at startup by the in-process XLA CPU client.

pub mod artifacts;
pub mod xla_exec;

pub use artifacts::{ArtifactSet, Manifest};
pub use xla_exec::{DetectorExec, Runtime, ThresholdExec};
