//! Artifact discovery + manifest validation.
//!
//! `make artifacts` writes `artifacts/{detector,threshold}.hlo.txt` plus
//! `manifest.json`. At load time we cross-check the manifest's baked-in
//! constants (batch/nmax/seek model) against this build's `SeekModel` so
//! the Rust mirror and the compiled kernels cannot drift apart silently.

use std::path::{Path, PathBuf};

use crate::device::seek::SeekModel;
use crate::runtime::{RtResult, RuntimeError};
use crate::util::json::Json;

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub nmax: usize,
    pub offset_pad: i32,
    pub percent_list_cap: usize,
    pub seek: SeekModel,
}

/// Paths + manifest for one artifact directory.
#[derive(Clone, Debug)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub detector_hlo: PathBuf,
    pub threshold_hlo: PathBuf,
    pub manifest: Manifest,
}

/// Default artifact directory: `$SSDUP_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("SSDUP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

impl Manifest {
    pub fn parse(text: &str) -> RtResult<Manifest> {
        let v = Json::parse(text).map_err(|e| RuntimeError(format!("manifest.json parse: {e}")))?;
        let get_i = |path: &[&str]| -> RtResult<i64> {
            v.at(path)
                .and_then(Json::as_i64)
                .ok_or_else(|| RuntimeError(format!("manifest missing int {path:?}")))
        };
        let get_f = |path: &[&str]| -> RtResult<f64> {
            v.at(path)
                .and_then(Json::as_f64)
                .ok_or_else(|| RuntimeError(format!("manifest missing num {path:?}")))
        };
        Ok(Manifest {
            batch: get_i(&["batch"])? as usize,
            nmax: get_i(&["nmax"])? as usize,
            offset_pad: get_i(&["offset_pad"])? as i32,
            percent_list_cap: get_i(&["percent_list_cap"])? as usize,
            seek: SeekModel {
                knee_sectors: get_i(&["seek_model", "knee_sectors"])?,
                short_base_us: get_f(&["seek_model", "short_base_us"])?,
                short_us_per_sector: get_f(&["seek_model", "short_us_per_sector"])?,
                long_base_us: get_f(&["seek_model", "long_base_us"])?,
                long_us_per_sector: get_f(&["seek_model", "long_us_per_sector"])?,
                cap_sectors: get_i(&["seek_model", "cap_sectors"])?,
            },
        })
    }

    /// Fail fast if the compiled kernels' constants differ from this
    /// build's native mirror.
    pub fn validate_against(&self, native: &SeekModel) -> RtResult<()> {
        if self.seek != *native {
            return Err(RuntimeError(format!(
                "artifact seek model {:?} != native seek model {:?}; \
                 re-run `make artifacts` after changing constants",
                self.seek, native
            )));
        }
        Ok(())
    }
}

impl ArtifactSet {
    /// Load and validate the artifact set under `dir`.
    pub fn load(dir: &Path) -> RtResult<ArtifactSet> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| RuntimeError(format!("reading {}: {e}", manifest_path.display())))?;
        let manifest = Manifest::parse(&text)?;
        manifest.validate_against(&SeekModel::default())?;
        let detector_hlo = dir.join("detector.hlo.txt");
        let threshold_hlo = dir.join("threshold.hlo.txt");
        for p in [&detector_hlo, &threshold_hlo] {
            if !p.exists() {
                return Err(RuntimeError(format!(
                    "missing artifact {} (run `make artifacts`)",
                    p.display()
                )));
            }
        }
        Ok(ArtifactSet { dir: dir.to_path_buf(), detector_hlo, threshold_hlo, manifest })
    }

    pub fn load_default() -> RtResult<ArtifactSet> {
        Self::load(&default_dir())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"{
      "version": 1, "batch": 16, "nmax": 512, "offset_pad": 2147483647,
      "percent_list_cap": 64,
      "seek_model": {"knee_sectors": 2048, "short_base_us": 500.0,
        "short_us_per_sector": 0.15, "long_base_us": 1500.0,
        "long_us_per_sector": 0.0025, "cap_sectors": 600000}
    }"#;

    #[test]
    fn parses_good_manifest() {
        let m = Manifest::parse(GOOD).unwrap();
        assert_eq!(m.batch, 16);
        assert_eq!(m.nmax, 512);
        assert_eq!(m.offset_pad, i32::MAX);
        assert_eq!(m.seek, SeekModel::default());
        m.validate_against(&SeekModel::default()).unwrap();
    }

    #[test]
    fn rejects_drifted_seek_model() {
        let bad = GOOD.replace("\"knee_sectors\": 2048", "\"knee_sectors\": 4096");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate_against(&SeekModel::default()).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"batch": 16}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn load_reports_missing_files() {
        let tmp = std::env::temp_dir().join(format!("ssdup-art-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), GOOD).unwrap();
        let err = ArtifactSet::load(&tmp).unwrap_err();
        assert!(err.to_string().contains("missing artifact"), "{err}");
        std::fs::remove_dir_all(&tmp).ok();
    }
}
