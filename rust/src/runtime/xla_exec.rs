//! XLA/PJRT execution wrappers (adapted from /opt/xla-example/load_hlo).
//!
//! One `Runtime` owns the PJRT CPU client; each artifact compiles once
//! into a `PjRtLoadedExecutable` and is then executed from the request
//! path with no Python anywhere. Input literals are marshalled from
//! reusable flat buffers (see §Perf in DESIGN.md).

use anyhow::{bail, Context, Result};

use crate::runtime::artifacts::ArtifactSet;
use crate::types::Detection;

/// PJRT client + compiled artifact executables.
pub struct Runtime {
    client: xla::PjRtClient,
    pub artifacts: ArtifactSet,
}

impl Runtime {
    /// Compile all artifacts on the CPU PJRT client.
    pub fn load(artifacts: ArtifactSet) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, artifacts })
    }

    pub fn load_default() -> Result<Runtime> {
        Self::load(ArtifactSet::load_default()?)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).with_context(|| format!("compile {}", path.display()))
    }

    pub fn detector(&self) -> Result<DetectorExec> {
        let exe = self.compile(&self.artifacts.detector_hlo)?;
        Ok(DetectorExec {
            exe,
            batch: self.artifacts.manifest.batch,
            nmax: self.artifacts.manifest.nmax,
            offset_pad: self.artifacts.manifest.offset_pad,
        })
    }

    pub fn threshold(&self) -> Result<ThresholdExec> {
        let exe = self.compile(&self.artifacts.threshold_hlo)?;
        Ok(ThresholdExec { exe, cap: self.artifacts.manifest.percent_list_cap })
    }
}

/// Compiled `detect(offsets, sizes, lengths)` module.
pub struct DetectorExec {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub nmax: usize,
    offset_pad: i32,
}

impl DetectorExec {
    /// Detect up to `batch` streams in one PJRT execution. Streams longer
    /// than `nmax` are rejected (lower the stream length or re-lower the
    /// artifact). Returns one `Detection` per input stream.
    pub fn run_batch(&self, streams: &[&[(i32, i32)]]) -> Result<Vec<Detection>> {
        if streams.len() > self.batch {
            bail!("batch {} > compiled batch {}", streams.len(), self.batch);
        }
        let b = self.batch;
        let n = self.nmax;
        let mut offsets = vec![self.offset_pad; b * n];
        let mut sizes = vec![0i32; b * n];
        let mut lengths = vec![0i32; b];
        for (i, s) in streams.iter().enumerate() {
            if s.len() > n {
                bail!("stream length {} > compiled nmax {}", s.len(), n);
            }
            for (j, &(off, size)) in s.iter().enumerate() {
                offsets[i * n + j] = off;
                sizes[i * n + j] = size;
            }
            lengths[i] = s.len() as i32;
        }
        let off_lit = xla::Literal::vec1(&offsets).reshape(&[b as i64, n as i64])?;
        let size_lit = xla::Literal::vec1(&sizes).reshape(&[b as i64, n as i64])?;
        let len_lit = xla::Literal::vec1(&lengths);
        let result = self.exe.execute::<xla::Literal>(&[off_lit, size_lit, len_lit])?[0][0]
            .to_literal_sync()?;
        let (s_lit, pct_lit, cost_lit) = result.to_tuple3()?;
        let s = s_lit.to_vec::<i32>()?;
        let pct = pct_lit.to_vec::<f32>()?;
        let cost = cost_lit.to_vec::<f32>()?;
        Ok(streams
            .iter()
            .enumerate()
            .map(|(i, _)| Detection { s: s[i], percentage: pct[i], seek_cost_us: cost[i] })
            .collect())
    }

    /// Detect a flat list of streams, chunking into compiled batches.
    pub fn run_all(&self, streams: &[Vec<(i32, i32)>]) -> Result<Vec<Detection>> {
        let mut out = Vec::with_capacity(streams.len());
        for chunk in streams.chunks(self.batch) {
            let refs: Vec<&[(i32, i32)]> = chunk.iter().map(|v| v.as_slice()).collect();
            out.extend(self.run_batch(&refs)?);
        }
        Ok(out)
    }
}

/// Compiled `threshold(percent_list, count)` module.
pub struct ThresholdExec {
    exe: xla::PjRtLoadedExecutable,
    pub cap: usize,
}

impl ThresholdExec {
    /// `sorted` must be ascending; returns (threshold, avgper).
    pub fn run(&self, sorted: &[f32]) -> Result<(f32, f32)> {
        if sorted.is_empty() {
            bail!("empty percent list");
        }
        if sorted.len() > self.cap {
            bail!("percent list {} > compiled cap {}", sorted.len(), self.cap);
        }
        let mut plist = vec![0f32; self.cap];
        plist[..sorted.len()].copy_from_slice(sorted);
        let p_lit = xla::Literal::vec1(&plist);
        let c_lit = xla::Literal::scalar(sorted.len() as i32);
        let result =
            self.exe.execute::<xla::Literal>(&[p_lit, c_lit])?[0][0].to_literal_sync()?;
        let (thr, avg) = result.to_tuple2()?;
        Ok((thr.to_vec::<f32>()?[0], avg.to_vec::<f32>()?[0]))
    }
}
