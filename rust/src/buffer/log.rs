//! Log-structured SSD append allocator (paper §2.5).
//!
//! Random writes are appended at the tail of the buffered file region so
//! the SSD only ever sees sequential writes (avoiding write amplification);
//! the AVL tree records where each original offset landed.

/// Monotone append cursor over a region's sector space.
#[derive(Clone, Copy, Debug, Default)]
pub struct AppendLog {
    cursor: i64,
}

impl AppendLog {
    pub fn new() -> Self {
        Self { cursor: 0 }
    }

    /// Allocate `sectors` at the tail; returns the SSD-relative offset.
    pub fn append(&mut self, sectors: i64) -> i64 {
        debug_assert!(sectors > 0);
        let at = self.cursor;
        self.cursor += sectors;
        at
    }

    /// Sectors consumed so far.
    pub fn used(&self) -> i64 {
        self.cursor
    }

    pub fn reset(&mut self) {
        self.cursor = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn appends_are_dense_and_monotone() {
        let mut log = AppendLog::new();
        let a = log.append(512);
        let b = log.append(128);
        let c = log.append(1);
        assert_eq!((a, b, c), (0, 512, 640));
        assert_eq!(log.used(), 641);
    }

    #[test]
    fn reset_rewinds() {
        let mut log = AppendLog::new();
        log.append(100);
        log.reset();
        assert_eq!(log.used(), 0);
        assert_eq!(log.append(5), 0);
    }
}
