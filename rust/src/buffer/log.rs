//! Log-structured SSD append allocator (paper §2.5).
//!
//! Random writes are appended at the tail of the buffered file region so
//! the SSD only ever sees sequential writes (avoiding write amplification);
//! the AVL tree records where each original offset landed.
//!
//! The allocator also tracks a **published watermark**: the high-water
//! sector up to which appended records' device bytes are known to be on
//! the backend (the live shard marks it at publish time). [`AppendLog::restore`]
//! — the recovery path that re-seats the cursor after a crash scan —
//! debug-asserts it never rewinds past that watermark: rewinding below a
//! published record would let the allocator hand its slots out again and
//! silently overwrite acknowledged data. The old `reset()` footgun (a
//! blind rewind with no such guard) survives only as the region-recycle
//! path, where the flusher has already settled every published byte.

/// Monotone append cursor over a region's sector space.
#[derive(Clone, Copy, Debug, Default)]
pub struct AppendLog {
    cursor: i64,
    /// sectors `[0, published)` belong to records whose device bytes have
    /// landed on the backend; the cursor must never rewind below this
    published: i64,
}

impl AppendLog {
    pub fn new() -> Self {
        Self { cursor: 0, published: 0 }
    }

    /// Allocate `sectors` at the tail; returns the SSD-relative offset.
    pub fn append(&mut self, sectors: i64) -> i64 {
        debug_assert!(sectors > 0);
        let at = self.cursor;
        self.cursor += sectors;
        at
    }

    /// Sectors consumed so far.
    pub fn used(&self) -> i64 {
        self.cursor
    }

    /// Record that every sector below `upto` now has its device bytes on
    /// the backend. Monotone; never exceeds the cursor (a record cannot
    /// publish slots that were never allocated).
    pub fn mark_published(&mut self, upto: i64) {
        debug_assert!(upto <= self.cursor, "published past the append cursor");
        if upto > self.published {
            self.published = upto;
        }
    }

    /// Published high-water mark, in sectors.
    pub fn published(&self) -> i64 {
        self.published
    }

    /// Re-seat the cursor after a crash-recovery scan: `cursor` is the
    /// end of the last surviving record. Recovery must never rewind past
    /// records already published — that would recycle live slots.
    pub fn restore(&mut self, cursor: i64) {
        debug_assert!(cursor >= 0);
        debug_assert!(
            cursor >= self.published,
            "restore({cursor}) rewinds past published records (published {})",
            self.published
        );
        self.cursor = cursor;
    }

    /// Full recycle (region flushed and settled): rewinds everything,
    /// including the published watermark — the flusher owns this path and
    /// calls it only after every published byte reached the HDD.
    pub fn reset(&mut self) {
        self.cursor = 0;
        self.published = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn appends_are_dense_and_monotone() {
        let mut log = AppendLog::new();
        let a = log.append(512);
        let b = log.append(128);
        let c = log.append(1);
        assert_eq!((a, b, c), (0, 512, 640));
        assert_eq!(log.used(), 641);
    }

    #[test]
    fn reset_rewinds() {
        let mut log = AppendLog::new();
        log.append(100);
        log.mark_published(100);
        log.reset();
        assert_eq!(log.used(), 0);
        assert_eq!(log.published(), 0, "recycle rewinds the watermark too");
        assert_eq!(log.append(5), 0);
    }

    #[test]
    fn publish_watermark_is_monotone_and_bounded() {
        let mut log = AppendLog::new();
        log.append(50);
        log.append(30);
        log.mark_published(50);
        assert_eq!(log.published(), 50);
        log.mark_published(20); // out-of-order publish completion
        assert_eq!(log.published(), 50, "watermark never regresses");
        log.mark_published(80);
        assert_eq!(log.published(), 80);
    }

    #[test]
    fn restore_seats_the_cursor_for_recovery() {
        let mut log = AppendLog::new();
        log.restore(640); // fresh log, cursor re-seated from a crash scan
        assert_eq!(log.used(), 640);
        assert_eq!(log.append(10), 640, "appends continue past the recovered tail");
    }

    #[test]
    #[should_panic(expected = "rewinds past published records")]
    #[cfg(debug_assertions)]
    fn restore_below_published_records_is_a_bug() {
        let mut log = AppendLog::new();
        log.append(100);
        log.mark_published(100);
        log.restore(50); // would hand published slots out again
    }

    #[test]
    #[should_panic(expected = "published past the append cursor")]
    #[cfg(debug_assertions)]
    fn publishing_unallocated_slots_is_a_bug() {
        let mut log = AppendLog::new();
        log.append(10);
        log.mark_published(11);
    }
}
