//! AVL tree for buffered-data metadata (paper §2.5).
//!
//! SSDUP+ appends random writes to SSD in log order, which destroys the
//! original offset order; this self-balancing BST keyed by *original*
//! offset restores it. An in-order traversal at flush time yields the
//! sequential HDD write order without a separate O(n log n) sort phase —
//! the paper's argument for AVL over a hash table.
//!
//! Implemented from scratch (arena-based, indices instead of boxes — this
//! is also the §Perf-relevant representation: one contiguous allocation,
//! no per-node malloc, cache-friendly traversal).

/// Arena-based AVL tree with `i64` keys (generic value payload).
///
/// Deleted slots go on a free list and are reused by later inserts, so a
/// long-lived tree under churn (the live engine's sector-ownership map
/// claims and releases extents continuously) stays one allocation.
#[derive(Clone, Debug)]
pub struct AvlTree<V> {
    nodes: Vec<Node<V>>,
    root: Option<u32>,
    free: Vec<u32>,
    len: usize,
}

#[derive(Clone, Debug)]
struct Node<V> {
    key: i64,
    value: V,
    left: Option<u32>,
    right: Option<u32>,
    height: i8,
}

impl<V> Default for AvlTree<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> AvlTree<V> {
    pub fn new() -> Self {
        Self { nodes: Vec::new(), root: None, free: Vec::new(), len: 0 }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { nodes: Vec::with_capacity(cap), root: None, free: Vec::new(), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes of metadata per node — the paper's 24-byte accounting
    /// (original offset, new offset, size) is the payload; we also count
    /// the structural fields so the overhead analysis is honest.
    pub fn approx_bytes(&self) -> usize {
        self.nodes.capacity() * std::mem::size_of::<Node<V>>() + std::mem::size_of::<Self>()
    }

    fn h(&self, n: Option<u32>) -> i8 {
        n.map_or(0, |i| self.nodes[i as usize].height)
    }

    fn update(&mut self, i: u32) {
        let (l, r) = {
            let n = &self.nodes[i as usize];
            (self.h(n.left), self.h(n.right))
        };
        self.nodes[i as usize].height = 1 + l.max(r);
    }

    fn balance_factor(&self, i: u32) -> i8 {
        let n = &self.nodes[i as usize];
        self.h(n.left) - self.h(n.right)
    }

    fn rotate_right(&mut self, y: u32) -> u32 {
        let x = self.nodes[y as usize].left.expect("rotate_right needs left child");
        let t2 = self.nodes[x as usize].right;
        self.nodes[x as usize].right = Some(y);
        self.nodes[y as usize].left = t2;
        self.update(y);
        self.update(x);
        x
    }

    fn rotate_left(&mut self, x: u32) -> u32 {
        let y = self.nodes[x as usize].right.expect("rotate_left needs right child");
        let t2 = self.nodes[y as usize].left;
        self.nodes[y as usize].left = Some(x);
        self.nodes[x as usize].right = t2;
        self.update(x);
        self.update(y);
        y
    }

    fn rebalance(&mut self, i: u32) -> u32 {
        self.update(i);
        let bf = self.balance_factor(i);
        if bf > 1 {
            let l = self.nodes[i as usize].left.unwrap();
            if self.balance_factor(l) < 0 {
                let nl = self.rotate_left(l);
                self.nodes[i as usize].left = Some(nl);
            }
            self.rotate_right(i)
        } else if bf < -1 {
            let r = self.nodes[i as usize].right.unwrap();
            if self.balance_factor(r) > 0 {
                let nr = self.rotate_right(r);
                self.nodes[i as usize].right = Some(nr);
            }
            self.rotate_left(i)
        } else {
            i
        }
    }

    /// Insert `key -> value`. Duplicate keys overwrite (a rewritten block
    /// supersedes the stale buffered copy — last write wins at flush).
    pub fn insert(&mut self, key: i64, value: V) {
        let root = self.root;
        self.root = Some(self.insert_at(root, key, value));
    }

    /// Allocate a node slot, preferring the free list over growing.
    fn alloc(&mut self, key: i64, value: V) -> u32 {
        self.len += 1;
        let node = Node { key, value, left: None, right: None, height: 1 };
        match self.free.pop() {
            Some(i) => {
                self.nodes[i as usize] = node;
                i
            }
            None => {
                let idx = self.nodes.len() as u32;
                self.nodes.push(node);
                idx
            }
        }
    }

    /// Return a node slot to the free list.
    fn release(&mut self, i: u32) {
        self.free.push(i);
        self.len -= 1;
    }

    fn insert_at(&mut self, node: Option<u32>, key: i64, value: V) -> u32 {
        let Some(i) = node else {
            return self.alloc(key, value);
        };
        match key.cmp(&self.nodes[i as usize].key) {
            std::cmp::Ordering::Less => {
                let l = self.nodes[i as usize].left;
                let nl = self.insert_at(l, key, value);
                self.nodes[i as usize].left = Some(nl);
            }
            std::cmp::Ordering::Greater => {
                let r = self.nodes[i as usize].right;
                let nr = self.insert_at(r, key, value);
                self.nodes[i as usize].right = Some(nr);
            }
            std::cmp::Ordering::Equal => {
                self.nodes[i as usize].value = value;
                return i;
            }
        }
        self.rebalance(i)
    }

    /// Remove `key`, returning its value. Rebalances on the way back up,
    /// so interleaved inserts and deletes keep the AVL height bound — the
    /// live engine's ownership map churns extents for the whole run.
    pub fn remove(&mut self, key: i64) -> Option<V>
    where
        V: Copy,
    {
        let root = self.root;
        let (new_root, removed) = self.remove_at(root, key);
        self.root = new_root;
        removed
    }

    fn remove_at(&mut self, node: Option<u32>, key: i64) -> (Option<u32>, Option<V>)
    where
        V: Copy,
    {
        let Some(i) = node else { return (None, None) };
        let removed;
        match key.cmp(&self.nodes[i as usize].key) {
            std::cmp::Ordering::Less => {
                let l = self.nodes[i as usize].left;
                let (nl, r) = self.remove_at(l, key);
                self.nodes[i as usize].left = nl;
                removed = r;
            }
            std::cmp::Ordering::Greater => {
                let r0 = self.nodes[i as usize].right;
                let (nr, r) = self.remove_at(r0, key);
                self.nodes[i as usize].right = nr;
                removed = r;
            }
            std::cmp::Ordering::Equal => {
                let val = self.nodes[i as usize].value;
                let (l, r) = (self.nodes[i as usize].left, self.nodes[i as usize].right);
                return match (l, r) {
                    (None, None) => {
                        self.release(i);
                        (None, Some(val))
                    }
                    (Some(c), None) | (None, Some(c)) => {
                        self.release(i);
                        (Some(self.rebalance(c)), Some(val))
                    }
                    (Some(_), Some(r)) => {
                        // two children: graft the in-order successor (min
                        // of the right subtree) into this slot, then
                        // delete the successor's old node below
                        let (succ_key, succ_val) = self.min_entry(r);
                        let (nr, _) = self.remove_at(Some(r), succ_key);
                        let n = &mut self.nodes[i as usize];
                        n.key = succ_key;
                        n.value = succ_val;
                        n.right = nr;
                        (Some(self.rebalance(i)), Some(val))
                    }
                };
            }
        }
        (Some(self.rebalance(i)), removed)
    }

    fn min_entry(&self, mut i: u32) -> (i64, V)
    where
        V: Copy,
    {
        while let Some(l) = self.nodes[i as usize].left {
            i = l;
        }
        (self.nodes[i as usize].key, self.nodes[i as usize].value)
    }

    /// Greatest entry with key strictly less than `key` (predecessor
    /// query — how the extent map finds a run starting left of a range).
    pub fn below(&self, key: i64) -> Option<(i64, &V)> {
        let mut best: Option<u32> = None;
        let mut cur = self.root;
        while let Some(i) = cur {
            let n = &self.nodes[i as usize];
            if n.key < key {
                best = Some(i);
                cur = n.right;
            } else {
                cur = n.left;
            }
        }
        best.map(|i| {
            let n = &self.nodes[i as usize];
            (n.key, &n.value)
        })
    }

    /// Is there any key in `[lo, hi)`? Allocation-free — hot-path guard
    /// queries (the ownership map's overlap check on every direct write)
    /// should not pay for materializing the range.
    pub fn any_in_range(&self, lo: i64, hi: i64) -> bool {
        // least key >= lo, then compare against hi
        let mut best: Option<i64> = None;
        let mut cur = self.root;
        while let Some(i) = cur {
            let n = &self.nodes[i as usize];
            if n.key >= lo {
                best = Some(n.key);
                cur = n.left;
            } else {
                cur = n.right;
            }
        }
        matches!(best, Some(k) if k < hi)
    }

    /// Is there any entry with key in `[lo, hi)` whose value satisfies
    /// `pred`? Allocation-free, like [`AvlTree::any_in_range`] — hot-path
    /// guard queries (the ownership map's pending-claim check on every
    /// live read) should not pay for materializing the range.
    pub fn any_in_range_where(&self, lo: i64, hi: i64, mut pred: impl FnMut(&V) -> bool) -> bool {
        self.any_where_node(self.root, lo, hi, &mut pred)
    }

    fn any_where_node(
        &self,
        node: Option<u32>,
        lo: i64,
        hi: i64,
        pred: &mut impl FnMut(&V) -> bool,
    ) -> bool {
        let Some(i) = node else { return false };
        let n = &self.nodes[i as usize];
        if n.key > lo && self.any_where_node(n.left, lo, hi, pred) {
            return true;
        }
        if n.key >= lo && n.key < hi && pred(&n.value) {
            return true;
        }
        n.key < hi && self.any_where_node(n.right, lo, hi, pred)
    }

    /// Entries with keys in `[lo, hi)`, ascending.
    pub fn range(&self, lo: i64, hi: i64) -> Vec<(i64, V)>
    where
        V: Copy,
    {
        let mut out = Vec::new();
        self.range_collect(self.root, lo, hi, &mut out);
        out
    }

    fn range_collect(&self, node: Option<u32>, lo: i64, hi: i64, out: &mut Vec<(i64, V)>)
    where
        V: Copy,
    {
        let Some(i) = node else { return };
        let n = &self.nodes[i as usize];
        let (key, value, left, right) = (n.key, n.value, n.left, n.right);
        if key > lo {
            self.range_collect(left, lo, hi, out);
        }
        if key >= lo && key < hi {
            out.push((key, value));
        }
        if key < hi {
            self.range_collect(right, lo, hi, out);
        }
    }

    pub fn get(&self, key: i64) -> Option<&V> {
        let mut cur = self.root;
        while let Some(i) = cur {
            let n = &self.nodes[i as usize];
            match key.cmp(&n.key) {
                std::cmp::Ordering::Less => cur = n.left,
                std::cmp::Ordering::Greater => cur = n.right,
                std::cmp::Ordering::Equal => return Some(&n.value),
            }
        }
        None
    }

    pub fn contains(&self, key: i64) -> bool {
        self.get(key).is_some()
    }

    /// In-order traversal (ascending key) — the flush order. Iterative
    /// with an explicit stack: flushing a multi-GB region must not
    /// overflow the call stack.
    pub fn in_order(&self) -> InOrder<'_, V> {
        let mut it = InOrder { tree: self, stack: Vec::with_capacity(self.height() as usize + 1) };
        it.push_left(self.root);
        it
    }

    /// Drain the tree into ascending (key, value) pairs, clearing it.
    pub fn drain_in_order(&mut self) -> Vec<(i64, V)>
    where
        V: Copy,
    {
        let out: Vec<(i64, V)> = self.in_order().map(|(k, v)| (k, *v)).collect();
        self.clear();
        out
    }

    pub fn clear(&mut self) {
        self.nodes.clear();
        self.free.clear();
        self.root = None;
        self.len = 0;
    }

    pub fn height(&self) -> i8 {
        self.h(self.root)
    }

    /// Validate AVL invariants (test/property-check hook).
    pub fn check_invariants(&self) -> Result<(), String> {
        fn go<V>(t: &AvlTree<V>, n: Option<u32>, lo: i64, hi: i64) -> Result<i8, String> {
            let Some(i) = n else { return Ok(0) };
            let node = &t.nodes[i as usize];
            if node.key <= lo || node.key >= hi {
                return Err(format!("BST violation at key {}", node.key));
            }
            let lh = go(t, node.left, lo, node.key)?;
            let rh = go(t, node.right, node.key, hi)?;
            if (lh - rh).abs() > 1 {
                return Err(format!("imbalance at key {}: {} vs {}", node.key, lh, rh));
            }
            let h = 1 + lh.max(rh);
            if h != node.height {
                return Err(format!("stale height at key {}: {} vs {}", node.key, node.height, h));
            }
            Ok(h)
        }
        go(self, self.root, i64::MIN, i64::MAX)?;
        let reachable = self.in_order().count();
        if reachable != self.len {
            return Err(format!("len {} but {} reachable nodes", self.len, reachable));
        }
        Ok(())
    }
}

/// Iterative in-order iterator.
pub struct InOrder<'a, V> {
    tree: &'a AvlTree<V>,
    stack: Vec<u32>,
}

impl<'a, V> InOrder<'a, V> {
    fn push_left(&mut self, mut n: Option<u32>) {
        while let Some(i) = n {
            self.stack.push(i);
            n = self.tree.nodes[i as usize].left;
        }
    }
}

impl<'a, V> Iterator for InOrder<'a, V> {
    type Item = (i64, &'a V);

    fn next(&mut self) -> Option<Self::Item> {
        let i = self.stack.pop()?;
        let n = &self.tree.nodes[i as usize];
        self.push_left(n.right);
        Some((n.key, &n.value))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn insert_and_get() {
        let mut t = AvlTree::new();
        for k in [5i64, 2, 8, 1, 9, 3] {
            t.insert(k, k * 10);
        }
        assert_eq!(t.len(), 6);
        assert_eq!(t.get(8), Some(&80));
        assert_eq!(t.get(7), None);
        t.check_invariants().unwrap();
    }

    #[test]
    fn duplicate_key_overwrites() {
        let mut t = AvlTree::new();
        t.insert(1, "old");
        t.insert(1, "new");
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(1), Some(&"new"));
    }

    #[test]
    fn any_in_range_where_matches_range_filter() {
        let mut t = AvlTree::new();
        for k in [5i64, 2, 8, 1, 9, 3, 14] {
            t.insert(k, k * 10);
        }
        // agrees with the materialized range on hits, misses, and bounds
        for (lo, hi) in [(0i64, 20), (2, 9), (4, 5), (5, 6), (9, 9), (10, 14), (15, 99)] {
            for want in [30i64, 80, 140, 999] {
                let via_range = t.range(lo, hi).iter().any(|(_, v)| *v == want);
                assert_eq!(
                    t.any_in_range_where(lo, hi, |v| *v == want),
                    via_range,
                    "lo={lo} hi={hi} want={want}"
                );
            }
        }
        assert!(!t.any_in_range_where(0, 100, |_| false), "predicate can reject everything");
        assert!(t.any_in_range_where(0, 100, |_| true));
        assert!(!AvlTree::<i64>::new().any_in_range_where(0, 100, |_| true), "empty tree");
    }

    #[test]
    fn in_order_is_sorted_ascending() {
        let mut t = AvlTree::new();
        let mut rng = Prng::new(42);
        let mut keys: Vec<i64> = (0..1000).map(|_| rng.gen_range(1_000_000) as i64).collect();
        for &k in &keys {
            t.insert(k, ());
        }
        keys.sort_unstable();
        keys.dedup();
        let got: Vec<i64> = t.in_order().map(|(k, _)| k).collect();
        assert_eq!(got, keys);
    }

    #[test]
    fn height_is_logarithmic_for_sequential_inserts() {
        // worst case for an unbalanced BST; AVL must stay ~1.44 log2(n)
        let mut t = AvlTree::new();
        let n = 4096;
        for k in 0..n {
            t.insert(k, ());
        }
        t.check_invariants().unwrap();
        let h = t.height() as f64;
        let bound = 1.44 * (n as f64 + 2.0).log2();
        assert!(h <= bound, "height {h} exceeds AVL bound {bound}");
    }

    #[test]
    fn drain_clears_and_returns_sorted() {
        let mut t = AvlTree::new();
        for k in [3i64, 1, 2] {
            t.insert(k, k);
        }
        let drained = t.drain_in_order();
        assert_eq!(drained, vec![(1, 1), (2, 2), (3, 3)]);
        assert!(t.is_empty());
        assert_eq!(t.in_order().count(), 0);
    }

    #[test]
    fn random_workload_keeps_invariants() {
        let mut rng = Prng::new(7);
        for trial in 0..20 {
            let mut t = AvlTree::new();
            let n = rng.range(1, 500);
            for _ in 0..n {
                t.insert(rng.gen_range(10_000) as i64, trial);
            }
            t.check_invariants().unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        }
    }

    #[test]
    fn remove_leaf_inner_and_root() {
        let mut t = AvlTree::new();
        for k in [50i64, 30, 70, 20, 40, 60, 80] {
            t.insert(k, k);
        }
        assert_eq!(t.remove(20), Some(20), "leaf");
        assert_eq!(t.remove(30), Some(30), "inner node with one child");
        assert_eq!(t.remove(50), Some(50), "root with two children");
        assert_eq!(t.remove(50), None, "double remove");
        assert_eq!(t.len(), 4);
        t.check_invariants().unwrap();
        let got: Vec<i64> = t.in_order().map(|(k, _)| k).collect();
        assert_eq!(got, vec![40, 60, 70, 80]);
    }

    #[test]
    fn update_then_remove_yields_latest_value() {
        let mut t = AvlTree::new();
        t.insert(5, "stale");
        t.insert(5, "fresh");
        assert_eq!(t.len(), 1);
        assert_eq!(t.remove(5), Some("fresh"), "duplicate insert must have overwritten");
        assert!(t.is_empty());
        assert_eq!(t.get(5), None);
    }

    #[test]
    fn removed_slots_are_reused() {
        let mut t = AvlTree::new();
        for k in 0..64i64 {
            t.insert(k, ());
        }
        let arena = t.nodes.len();
        for k in 0..32i64 {
            t.remove(k);
        }
        for k in 100..132i64 {
            t.insert(k, ());
        }
        assert_eq!(t.nodes.len(), arena, "churn must not grow the arena");
        assert_eq!(t.len(), 64);
        t.check_invariants().unwrap();
    }

    #[test]
    fn random_insert_remove_matches_model() {
        let mut rng = Prng::new(23);
        let mut t = AvlTree::new();
        let mut model = std::collections::BTreeMap::new();
        for _ in 0..4000 {
            let k = rng.gen_range(300) as i64;
            if rng.chance(0.4) {
                assert_eq!(t.remove(k), model.remove(&k), "remove {k}");
            } else {
                t.insert(k, k * 3);
                model.insert(k, k * 3);
            }
        }
        t.check_invariants().unwrap();
        let got: Vec<(i64, i64)> = t.in_order().map(|(k, v)| (k, *v)).collect();
        let want: Vec<(i64, i64)> = model.into_iter().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn below_and_range_queries() {
        let mut t = AvlTree::new();
        for k in [10i64, 20, 30, 40, 50] {
            t.insert(k, k);
        }
        assert_eq!(t.below(10), None);
        assert_eq!(t.below(11).map(|(k, _)| k), Some(10));
        assert_eq!(t.below(45).map(|(k, _)| k), Some(40));
        assert_eq!(t.below(i64::MAX).map(|(k, _)| k), Some(50));
        let keys: Vec<i64> = t.range(15, 45).into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec![20, 30, 40]);
        assert_eq!(t.range(20, 21).len(), 1, "inclusive lower bound");
        assert!(t.range(41, 50).is_empty(), "exclusive upper bound");
        assert!(t.any_in_range(15, 45));
        assert!(t.any_in_range(20, 21), "inclusive lower bound");
        assert!(!t.any_in_range(41, 50), "exclusive upper bound");
        assert!(!t.any_in_range(51, 100));
    }

    #[test]
    fn metadata_overhead_is_tiny_fraction() {
        // paper: ~3 MB of AVL for 40 GB / 256 KB requests (163840 nodes).
        let mut t = AvlTree::with_capacity(163_840);
        for k in 0..163_840i64 {
            t.insert(k * 512, (k, 512i32));
        }
        let bytes = t.approx_bytes();
        let data_bytes = 40u64 * 1024 * 1024 * 1024;
        let frac = bytes as f64 / data_bytes as f64;
        assert!(frac < 0.001, "metadata fraction {frac}");
    }
}
