//! Two-region pipelined SSD buffer + flushing strategies (paper §2.4).
//!
//! The SSD is split into two equal regions: one receives writes while the
//! other flushes, so data buffering and flushing overlap without having to
//! predict computation-phase durations (Eq. 4–7 analysis). The *flush
//! strategy* decides when a full region may start (or continue) flushing:
//!
//! * `Immediate` — SSDUP: flush as soon as a region fills.
//! * `TrafficAware` — SSDUP+: pause flushing while the current traffic's
//!   random percentage is low (most writes are then going directly to
//!   HDD, and a concurrent flush would interfere — §2.4.2).
//! * OrangeFS-BB is modeled in `baseline/` as a single region covering the
//!   whole SSD with blocking flush.

use crate::buffer::region::{FlushExtent, Region};

/// When a full region is allowed to flush.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FlushStrategy {
    /// start immediately when a region fills (SSDUP)
    Immediate,
    /// pause while current random percentage < `pause_below` and direct
    /// HDD traffic is present (SSDUP+ traffic-aware strategy)
    TrafficAware { pause_below: f32 },
}

impl FlushStrategy {
    /// May a flush chunk be issued right now?
    ///
    /// `current_percentage` is the detector's randomness estimate of the
    /// most recent request stream; `hdd_direct_active` reports whether any
    /// direct-to-HDD writes are queued or in flight; `drained` reports
    /// whether the producing applications have finished (then flushing
    /// must proceed regardless — the paper's third flush completes after
    /// the IOR instances finish writing).
    pub fn allow_flush(
        &self,
        current_percentage: f32,
        hdd_direct_active: bool,
        drained: bool,
    ) -> bool {
        match *self {
            FlushStrategy::Immediate => true,
            FlushStrategy::TrafficAware { pause_below } => {
                if drained || !hdd_direct_active {
                    true
                } else {
                    current_percentage >= pause_below
                }
            }
        }
    }
}

/// Outcome of trying to buffer one request into the pipeline.
///
/// A successful outcome is a **slot reservation**, not a completed
/// transfer: the pipeline hands out `(region, ssd_offset)` and updates
/// its metadata, and the caller writes the device bytes afterwards. The
/// DES simulator does both under one event; the live shard deliberately
/// writes *outside* its core lock (reserve→publish ingest) and tracks
/// the in-flight window in its ownership map, so concurrent clients
/// overlap their device writes. Either way the pipeline's invariant is
/// the same: a region handed to the flusher stops accepting
/// reservations, so the flusher's copy set is final once the in-flight
/// reservations on that region have completed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufferOutcome {
    /// slot reserved in the active region at this SSD offset
    Buffered { region: usize, ssd_offset: i64 },
    /// slot reserved, and the active region is now switching: the
    /// previously active region became full and should start flushing
    BufferedAndFull { region: usize, ssd_offset: i64, flush_region: usize },
    /// both regions unavailable — request must wait (the paper: "the
    /// system waits until a region becomes empty")
    Blocked,
}

/// Two-region pipeline state machine.
#[derive(Clone, Debug)]
pub struct Pipeline {
    regions: [Region; 2],
    active: usize,
    /// region currently being flushed (at most one at a time: both halves
    /// share the one SSD and the one HDD)
    flushing: Option<usize>,
    /// regions that filled up and wait for the flusher
    pub flush_pending: Vec<usize>,
    // stats
    pub flushes_started: u64,
    pub blocked_events: u64,
}

impl Pipeline {
    /// `total_capacity_sectors` is the whole SSD budget; each region gets
    /// half (paper §2.4.1).
    pub fn new(total_capacity_sectors: i64) -> Self {
        assert!(total_capacity_sectors >= 2);
        let half = total_capacity_sectors / 2;
        Self {
            regions: [Region::new(half), Region::new(half)],
            active: 0,
            flushing: None,
            flush_pending: Vec::new(),
            flushes_started: 0,
            blocked_events: 0,
        }
    }

    pub fn active_region(&self) -> usize {
        self.active
    }

    pub fn flushing_region(&self) -> Option<usize> {
        self.flushing
    }

    pub fn region(&self, i: usize) -> &Region {
        &self.regions[i]
    }

    pub fn used_sectors(&self) -> i64 {
        self.regions.iter().map(|r| r.used()).sum()
    }

    /// Is `r` neither flushing nor queued to flush? A region handed to
    /// the flusher must never accept appends: the flusher resolves its
    /// log slots into copy addresses, so a concurrent append would write
    /// new bytes under extents being copied to *old* HDD locations.
    fn appendable(&self, r: usize) -> bool {
        self.flushing != Some(r) && !self.flush_pending.contains(&r)
    }

    /// Try to reserve a slot for a request of `size` sectors for `file`
    /// at `orig_offset`. Implements the §2.4.1 region switch. See
    /// [`BufferOutcome`] for the reservation semantics.
    pub fn buffer(&mut self, file: u32, orig_offset: i64, size: i64) -> BufferOutcome {
        let a = self.active;
        let a_appendable = self.appendable(a);
        if a_appendable {
            if let Some(ssd_offset) = self.regions[a].buffer(file, orig_offset, size) {
                return BufferOutcome::Buffered { region: a, ssd_offset };
            }
        }
        // active region full (or already handed to the flusher): try the
        // other one if it is empty. `active` only switches after a
        // *successful* buffer — flipping first (and bailing when the
        // write does not fit the empty region either) would leave the
        // full region active-in-name-only and never queued for flushing,
        // starving the flusher while blocked ingest waits forever.
        let b = 1 - a;
        let other_free = self.regions[b].is_empty() && self.appendable(b);
        if other_free {
            if let Some(ssd_offset) = self.regions[b].buffer(file, orig_offset, size) {
                self.active = b;
                // report BufferedAndFull only when this call actually
                // queued the old region; if it was already handed to the
                // flusher (or empty), nothing new needs flushing
                if a_appendable && !self.regions[a].is_empty() {
                    self.flush_pending.push(a);
                    return BufferOutcome::BufferedAndFull { region: b, ssd_offset, flush_region: a };
                }
                return BufferOutcome::Buffered { region: b, ssd_offset };
            }
        }
        self.blocked_events += 1;
        BufferOutcome::Blocked
    }

    /// Next region waiting to flush, if the flusher is idle.
    pub fn next_flush(&mut self) -> Option<usize> {
        if self.flushing.is_some() {
            return None;
        }
        if self.flush_pending.is_empty() {
            return None;
        }
        let r = self.flush_pending.remove(0);
        self.flushing = Some(r);
        self.flushes_started += 1;
        Some(r)
    }

    /// Force the active region into the flush queue (end-of-run drain).
    pub fn enqueue_residual_flush(&mut self) -> bool {
        let a = self.active;
        if !self.regions[a].is_empty() && !self.flush_pending.contains(&a) && self.flushing != Some(a) {
            self.flush_pending.push(a);
            true
        } else {
            false
        }
    }

    /// Drain the flushing region's metadata into ordered flush extents.
    pub fn drain_flushing(&mut self) -> Vec<FlushExtent> {
        let r = self.flushing.expect("drain without active flush");
        self.regions[r].drain_for_flush()
    }

    /// Reset the flushing region without building flush extents — for
    /// flushers that resolve their copy set elsewhere (the live shard's
    /// ownership map).
    pub fn reset_flushing(&mut self) {
        let r = self.flushing.expect("reset without active flush");
        self.regions[r].reset();
    }

    /// Mark `region`'s log sectors below `upto` as published — the live
    /// shard calls this when a reserved slot's device bytes land, so the
    /// recovery path's rewind guard ([`crate::buffer::log::AppendLog::restore`])
    /// has teeth.
    pub fn mark_published(&mut self, region: usize, upto: i64) {
        self.regions[region].mark_published(upto);
    }

    /// Crash recovery: re-seat both regions over their scanned log tails
    /// and restore the flush topology. `active` accepts new appends;
    /// `queue` (oldest first, by record sequence) goes to the flusher —
    /// recovery must preserve fill-order flushing, because the replay
    /// watermarks assume an older region never flushes after a newer one.
    pub fn restore(&mut self, used: [i64; 2], active: usize, queue: &[usize]) {
        assert!(active < 2);
        assert!(
            self.used_sectors() == 0 && self.flushing.is_none() && self.flush_pending.is_empty(),
            "restore on a fresh pipeline only"
        );
        for (i, &u) in used.iter().enumerate() {
            self.regions[i].restore(u);
        }
        self.active = active;
        for &r in queue {
            assert!(r < 2 && r != active, "queued region must be the inactive one");
            assert!(!self.regions[r].is_empty(), "queued region must hold recovered data");
            self.flush_pending.push(r);
        }
    }

    /// The flusher finished writing the drained extents to HDD.
    pub fn flush_done(&mut self) {
        assert!(self.flushing.is_some(), "flush_done without flush");
        self.flushing = None;
    }

    /// Is any buffered data left anywhere?
    pub fn dirty(&self) -> bool {
        self.flushing.is_some()
            || !self.flush_pending.is_empty()
            || self.regions.iter().any(|r| !r.is_empty())
    }

    pub fn metadata_bytes(&self) -> usize {
        self.regions.iter().map(|r| r.metadata_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pl(total: i64) -> Pipeline {
        Pipeline::new(total)
    }

    #[test]
    fn fills_active_then_switches() {
        let mut p = pl(2000); // two regions of 1000
        for i in 0..2 {
            match p.buffer(1, i * 500, 500) {
                BufferOutcome::Buffered { region: 0, .. } => {}
                o => panic!("unexpected {o:?}"),
            }
        }
        // region 0 now full; next buffer lands in region 1 and queues 0
        match p.buffer(1, 5000, 500) {
            BufferOutcome::BufferedAndFull { region: 1, flush_region: 0, .. } => {}
            o => panic!("unexpected {o:?}"),
        }
        assert_eq!(p.active_region(), 1);
        assert_eq!(p.next_flush(), Some(0));
        assert_eq!(p.next_flush(), None, "only one flush at a time");
    }

    #[test]
    fn blocks_when_both_regions_unavailable() {
        let mut p = pl(2000);
        p.buffer(1, 0, 1000); // fill region 0
        p.buffer(1, 2000, 1000); // switch, fill region 1
        let started = p.next_flush();
        assert_eq!(started, Some(0));
        // region 0 is flushing (not yet drained/done), region 1 full
        assert_eq!(p.buffer(1, 9000, 10), BufferOutcome::Blocked);
        assert_eq!(p.blocked_events, 1);
        // complete the flush; region 0 empty again
        let extents = p.drain_flushing();
        assert!(!extents.is_empty());
        p.flush_done();
        match p.buffer(1, 9000, 10) {
            BufferOutcome::BufferedAndFull { region: 0, flush_region: 1, .. } => {}
            o => panic!("unexpected {o:?}"),
        }
    }

    #[test]
    fn oversized_write_does_not_strand_the_full_region() {
        let mut p = pl(2000); // two regions of 1000
        p.buffer(1, 0, 1000); // fill region 0 exactly
        // a write too large even for the empty region must not flip
        // `active`: regression for the switch-before-buffer bug
        assert_eq!(p.buffer(1, 5000, 1001), BufferOutcome::Blocked);
        assert_eq!(p.active_region(), 0, "active switches only after a successful buffer");
        // a region-sized write still triggers the switch and queues the
        // full region for the flusher
        match p.buffer(1, 9000, 500) {
            BufferOutcome::BufferedAndFull { region: 1, flush_region: 0, .. } => {}
            o => panic!("unexpected {o:?}"),
        }
        assert_eq!(p.next_flush(), Some(0), "the full region reaches the flusher");
    }

    #[test]
    fn pipeline_conservation_of_bytes() {
        let mut p = pl(4000);
        let mut buffered = 0i64;
        let mut flushed = 0i64;
        let mut off = 0i64;
        for _ in 0..40 {
            match p.buffer(2, off, 100) {
                BufferOutcome::Buffered { .. } => buffered += 100,
                BufferOutcome::BufferedAndFull { .. } => buffered += 100,
                BufferOutcome::Blocked => {
                    if p.next_flush().is_some() {
                        flushed += p.drain_flushing().iter().map(|e| e.size).sum::<i64>();
                        p.flush_done();
                    }
                    continue;
                }
            }
            off += 100;
        }
        p.enqueue_residual_flush();
        while p.next_flush().is_some() {
            flushed += p.drain_flushing().iter().map(|e| e.size).sum::<i64>();
            p.flush_done();
        }
        // note: active region may still hold data if it wasn't enqueued
        assert_eq!(buffered, flushed + p.used_sectors());
    }

    #[test]
    fn traffic_aware_strategy_pauses_and_resumes() {
        let s = FlushStrategy::TrafficAware { pause_below: 0.5 };
        assert!(!s.allow_flush(0.2, true, false), "low randomness + direct traffic -> pause");
        assert!(s.allow_flush(0.8, true, false), "high randomness -> flush");
        assert!(s.allow_flush(0.2, false, false), "no direct traffic -> flush");
        assert!(s.allow_flush(0.0, true, true), "drained -> always flush");
        let imm = FlushStrategy::Immediate;
        assert!(imm.allow_flush(0.0, true, false), "SSDUP never pauses");
    }

    #[test]
    fn queued_region_never_accepts_appends() {
        let mut p = pl(2000);
        p.buffer(1, 0, 10); // partially-filled active region 0
        assert!(p.enqueue_residual_flush()); // forced out early (drain/valve)
        // region 0 is queued: appends must go to region 1 even though 0
        // has plenty of space — its log slots now belong to the flusher.
        // Plain Buffered: this call queued nothing new (0 already is).
        match p.buffer(1, 100, 10) {
            BufferOutcome::Buffered { region: 1, .. } => {}
            o => panic!("unexpected {o:?}"),
        }
        assert_eq!(p.active_region(), 1);
        assert_eq!(p.next_flush(), Some(0));
        // and while region 0 flushes, it still accepts nothing
        let extents = p.drain_flushing();
        assert_eq!(extents.len(), 1);
        match p.buffer(1, 200, 10) {
            BufferOutcome::Buffered { region: 1, .. } => {}
            o => panic!("unexpected {o:?}"),
        }
        p.flush_done();
    }

    #[test]
    fn restore_reseats_regions_and_preserves_flush_order() {
        let mut p = pl(2000);
        // crash left region 1 full (older burst) and region 0 half full
        // (it was active): region 1 must reach the flusher first, region 0
        // keeps accepting appends after its recovered tail
        p.restore([500, 1000], 0, &[1]);
        assert_eq!(p.active_region(), 0);
        assert_eq!(p.used_sectors(), 1500);
        assert!(p.dirty());
        assert_eq!(p.next_flush(), Some(1), "recovered queue order preserved");
        match p.buffer(1, 0, 100) {
            BufferOutcome::Buffered { region: 0, ssd_offset: 500 } => {}
            o => panic!("appends must continue past the recovered tail, got {o:?}"),
        }
        p.drain_flushing();
        p.flush_done();
        assert_eq!(p.region(1).used(), 0, "recovered region flushes clean");
    }

    #[test]
    fn residual_flush_only_once() {
        let mut p = pl(2000);
        p.buffer(1, 0, 10);
        assert!(p.enqueue_residual_flush());
        assert!(!p.enqueue_residual_flush(), "no duplicate enqueue");
        assert!(p.dirty());
        p.next_flush().unwrap();
        p.drain_flushing();
        p.flush_done();
        assert!(!p.dirty());
    }
}
