//! One SSD buffer region: an append log plus per-file AVL metadata.
//!
//! The paper divides the SSD into two equal regions (§2.4); each region
//! independently tracks what it buffered so it can be flushed back to HDD
//! in original-offset order (§2.5: one AVL tree per file, in-order
//! traversal = sequential flush, random *reads* from SSD are cheap).

use std::collections::HashMap;

use crate::buffer::avl::AvlTree;
use crate::buffer::log::AppendLog;

/// Value stored per buffered extent: where it landed in the SSD log.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BufferedExtent {
    pub ssd_offset: i64,
    pub size: i32,
}

/// A flush unit: original file location + where to read it from SSD.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlushExtent {
    pub file: u32,
    pub orig_offset: i64,
    pub ssd_offset: i64,
    pub size: i64,
}

#[derive(Clone, Debug)]
pub struct Region {
    pub capacity_sectors: i64,
    used: i64,
    log: AppendLog,
    trees: HashMap<u32, AvlTree<BufferedExtent>>,
    buffered_requests: u64,
}

impl Region {
    pub fn new(capacity_sectors: i64) -> Self {
        assert!(capacity_sectors > 0);
        Self {
            capacity_sectors,
            used: 0,
            log: AppendLog::new(),
            trees: HashMap::new(),
            buffered_requests: 0,
        }
    }

    pub fn used(&self) -> i64 {
        self.used
    }

    pub fn free(&self) -> i64 {
        self.capacity_sectors - self.used
    }

    pub fn is_empty(&self) -> bool {
        self.used == 0
    }

    pub fn is_full_for(&self, sectors: i64) -> bool {
        self.used + sectors > self.capacity_sectors
    }

    pub fn buffered_requests(&self) -> u64 {
        self.buffered_requests
    }

    pub fn files(&self) -> usize {
        self.trees.len()
    }

    /// Buffer a write: append to the log, record metadata. Returns the SSD
    /// offset, or None if the region cannot hold it.
    pub fn buffer(&mut self, file: u32, orig_offset: i64, size: i64) -> Option<i64> {
        if self.is_full_for(size) {
            return None;
        }
        let ssd_offset = self.log.append(size);
        self.used += size;
        self.buffered_requests += 1;
        self.trees
            .entry(file)
            .or_default()
            .insert(orig_offset, BufferedExtent { ssd_offset, size: size as i32 });
        Some(ssd_offset)
    }

    /// Total AVL metadata bytes (paper Table-1 "AVL cost" accounting).
    pub fn metadata_bytes(&self) -> usize {
        self.trees.values().map(|t| t.approx_bytes()).sum()
    }

    /// Drain the region for flushing: per file (ascending handle), extents
    /// in ascending *original* offset, with offset-adjacent extents merged
    /// into single sequential runs (they are also adjacent in the SSD log
    /// iff they were appended consecutively; merged only when both sides
    /// are contiguous so one SSD read + one HDD write suffices).
    ///
    /// The returned extents restore *order*, not *versions*: a same-key
    /// rewrite replaces its metadata entry here, but partial overlaps and
    /// cross-region/cross-route rewrites leave stale ranges behind. The
    /// DES flusher uses this output directly (the simulator models
    /// write-once bursts); the live flusher only uses this call to reset
    /// the region and instead copies the surviving extents recorded in
    /// the shard's sector-ownership map (`live::ownership`), so only the
    /// newest copies reach the HDD.
    pub fn drain_for_flush(&mut self) -> Vec<FlushExtent> {
        let mut files: Vec<u32> = self.trees.keys().copied().collect();
        files.sort_unstable();
        let mut out = Vec::new();
        for file in files {
            let mut tree = self.trees.remove(&file).unwrap();
            let mut run: Option<FlushExtent> = None;
            for (orig, ext) in tree.drain_in_order() {
                match run.as_mut() {
                    Some(r)
                        if r.orig_offset + r.size == orig
                            && r.ssd_offset + r.size == ext.ssd_offset =>
                    {
                        r.size += ext.size as i64;
                    }
                    _ => {
                        if let Some(r) = run.take() {
                            out.push(r);
                        }
                        run = Some(FlushExtent {
                            file,
                            orig_offset: orig,
                            ssd_offset: ext.ssd_offset,
                            size: ext.size as i64,
                        });
                    }
                }
            }
            if let Some(r) = run.take() {
                out.push(r);
            }
        }
        self.reset();
        out
    }

    /// Clear the region's metadata and log without materializing flush
    /// extents — the live flusher's reset path (its copy set comes from
    /// the shard's sector-ownership map, so building the sorted extent
    /// list here would be thrown away).
    pub fn reset(&mut self) {
        self.trees.clear();
        self.used = 0;
        self.log.reset();
        self.buffered_requests = 0;
    }

    /// Mark the log sectors below `upto` as published (device bytes on
    /// the backend) — see [`AppendLog::mark_published`].
    pub fn mark_published(&mut self, upto: i64) {
        self.log.mark_published(upto);
    }

    /// Crash recovery: re-seat the region over `used` sectors of
    /// already-written log (the end of the last surviving record found by
    /// the scan). The per-file metadata trees are *not* rebuilt — the
    /// live flusher's copy set comes from the shard's ownership map, and
    /// that map is rebuilt by replay.
    pub fn restore(&mut self, used: i64) {
        assert!(
            (0..=self.capacity_sectors).contains(&used),
            "restored region tail {used} outside capacity {}",
            self.capacity_sectors
        );
        debug_assert!(self.used == 0 && self.trees.is_empty(), "restore on a fresh region");
        self.used = used;
        self.log.restore(used);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_until_full() {
        let mut r = Region::new(1000);
        assert_eq!(r.buffer(1, 0, 600), Some(0));
        assert!(r.is_full_for(600));
        assert_eq!(r.buffer(1, 600, 600), None, "over capacity rejected");
        assert_eq!(r.buffer(1, 600, 400), Some(600));
        assert_eq!(r.free(), 0);
    }

    #[test]
    fn drain_restores_original_order() {
        let mut r = Region::new(10_000);
        // arrival order scrambled; offsets 0,512,1024 for file 3
        r.buffer(3, 1024, 512);
        r.buffer(3, 0, 512);
        r.buffer(3, 512, 512);
        let extents = r.drain_for_flush();
        // offsets are adjacent but SSD log order is 1024,0,512: extents
        // (0) and (512) are contiguous in file AND log -> merged to one
        // 1024-sector run; (1024) sits at log offset 0 -> separate.
        assert_eq!(extents.len(), 2);
        assert_eq!(extents[0].orig_offset, 0);
        assert_eq!(extents[0].size, 1024);
        assert_eq!(extents[1].orig_offset, 1024);
        assert_eq!(extents[1].ssd_offset, 0);
        assert!(r.is_empty());
    }

    #[test]
    fn drain_merges_in_order_appends() {
        let mut r = Region::new(10_000);
        // appended in offset order -> contiguous in log AND in file
        for i in 0..8i64 {
            r.buffer(1, i * 512, 512);
        }
        let extents = r.drain_for_flush();
        assert_eq!(extents.len(), 1, "single merged run");
        assert_eq!(extents[0].size, 8 * 512);
        assert_eq!(extents[0].ssd_offset, 0);
    }

    #[test]
    fn drain_orders_multiple_files() {
        let mut r = Region::new(10_000);
        r.buffer(9, 0, 128);
        r.buffer(2, 512, 128);
        r.buffer(2, 0, 128);
        let extents = r.drain_for_flush();
        assert_eq!(extents.iter().map(|e| e.file).collect::<Vec<_>>(), vec![2, 2, 9]);
        assert_eq!(extents[0].orig_offset, 0);
        assert_eq!(extents[1].orig_offset, 512);
    }

    #[test]
    fn rewrite_same_offset_keeps_latest() {
        let mut r = Region::new(10_000);
        r.buffer(1, 0, 512);
        let second = r.buffer(1, 0, 512).unwrap();
        let extents = r.drain_for_flush();
        assert_eq!(extents.len(), 1);
        assert_eq!(extents[0].ssd_offset, second, "latest copy wins");
    }

    #[test]
    fn metadata_bytes_grow_with_entries() {
        let mut r = Region::new(1 << 30);
        let before = r.metadata_bytes();
        for i in 0..1000i64 {
            r.buffer(1, i * 1024, 512);
        }
        assert!(r.metadata_bytes() > before);
        assert_eq!(r.buffered_requests(), 1000);
        assert_eq!(r.files(), 1);
    }
}
