//! SSD buffer management: log-structured appends, AVL metadata, and the
//! two-region flush pipeline (paper §2.4–2.5).

pub mod avl;
pub mod log;
pub mod pipeline;
pub mod region;

pub use avl::AvlTree;
pub use log::AppendLog;
pub use pipeline::{BufferOutcome, FlushStrategy, Pipeline};
pub use region::{BufferedExtent, FlushExtent, Region};
