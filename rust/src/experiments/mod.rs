//! Experiment registry: one entry per paper table/figure (DESIGN.md's
//! experiment index). `ssdup exp <id>` regenerates any of them.

pub mod ablations;
pub mod common;
pub mod fig_adaptive;
pub mod fig_flush;
pub mod fig_ior_baseline;
pub mod fig_limited_ssd;
pub mod fig_main;
pub mod fig_offsets;
pub mod fig_other_benchmarks;
pub mod table_overhead;

pub use common::{Report, Scale};

/// All experiment ids, in paper order.
pub fn all_ids() -> Vec<&'static str> {
    vec![
        "fig2", "fig3", "fig5", "fig6", "fig7", "fig8", "fig9", "fig11", "fig12", "fig13",
        "fig14", "fig15", "fig16", "table1", "ablation-log", "ablation-pipeline",
        "ablation-threshold",
    ]
}

/// Run one experiment by id.
pub fn run(id: &str, scale: Scale) -> Option<Report> {
    Some(match id {
        "fig2" => fig_ior_baseline::fig2(scale),
        "fig3" => fig_offsets::fig3(scale),
        "fig5" => fig_offsets::fig5(scale),
        "fig6" => fig_ior_baseline::fig6(scale),
        "fig7" => fig_adaptive::fig7(scale),
        "fig8" => fig_adaptive::fig8(scale),
        "fig9" => fig_flush::fig9(scale),
        "fig11" => fig_main::fig11(scale),
        "fig12" => fig_main::fig12(scale),
        "fig13" => fig_limited_ssd::fig13(scale),
        "fig14" => fig_limited_ssd::fig14(scale),
        "fig15" => fig_other_benchmarks::fig15(scale),
        "fig16" => fig_other_benchmarks::fig16(scale),
        "table1" => table_overhead::table1(scale),
        "ablation-log" => ablations::ablation_log(scale),
        "ablation-pipeline" => ablations::ablation_pipeline(scale),
        "ablation-threshold" => ablations::ablation_threshold(scale),
        _ => return None,
    })
}
