//! Fig 2 + Fig 6: native-OrangeFS IOR characterization.
//!
//! Fig 2 — throughput of segmented-contiguous / segmented-random / strided
//! IOR as the process count grows (4..128): contiguous and strided rise
//! then fall (CFQ merge window saturates), random stays flat and low.
//!
//! Fig 6 — strided IOR: throughput decreases while the detector's random
//! percentage increases with the process count (the inverse correlation
//! that justifies percentage-driven redirection).

use crate::experiments::common::{f1, ior_w, pct, run_system, Report, Scale};
use crate::server::SystemKind;
use crate::util::json::Json;
use crate::util::stats;
use crate::workload::ior::IorPattern;

pub fn fig2(scale: Scale) -> Report {
    let mut rep = Report::new("fig2", "IOR throughput vs process count, native OrangeFS");
    rep.columns(&["procs", "seg-contiguous MB/s", "strided MB/s", "seg-random MB/s"]);
    let mut data = Vec::new();
    for procs in [4u32, 8, 16, 32, 64, 128] {
        let mut cells = vec![procs.to_string()];
        let mut obj = vec![("procs", Json::from(procs as u64))];
        for (key, pattern) in [
            ("contig", IorPattern::SegmentedContiguous),
            ("strided", IorPattern::Strided),
            ("random", IorPattern::SegmentedRandom),
        ] {
            let w = ior_w(0, pattern, procs, scale.gb16(), scale, 0);
            let r = run_system(SystemKind::OrangeFs, &w, scale, |_| {});
            cells.push(f1(r.throughput_mbps()));
            obj.push((key, Json::Num(r.throughput_mbps())));
        }
        // keep column order contig, strided, random
        let c = cells.remove(2);
        cells.insert(2, c);
        rep.row(cells);
        data.push(Json::obj(obj));
    }
    rep.note("paper: contiguous 218->150 MB/s, strided 164->107, random ~95 flat");
    rep.data = Json::Arr(data);
    rep
}

pub fn fig6(scale: Scale) -> Report {
    let mut rep = Report::new(
        "fig6",
        "strided IOR: throughput vs random percentage as processes grow (OrangeFS)",
    );
    rep.columns(&["procs", "throughput MB/s", "random %"]);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut data = Vec::new();
    for procs in [8u32, 16, 32, 64, 128] {
        let w = ior_w(0, IorPattern::Strided, procs, scale.gb16(), scale, 0);
        let r = run_system(SystemKind::OrangeFs, &w, scale, |_| {});
        rep.row(vec![procs.to_string(), f1(r.throughput_mbps()), pct(r.mean_percentage)]);
        xs.push(r.mean_percentage);
        ys.push(r.throughput_mbps());
        data.push(Json::obj(vec![
            ("procs", Json::from(procs as u64)),
            ("mbps", Json::Num(r.throughput_mbps())),
            ("random_pct", Json::Num(r.mean_percentage)),
        ]));
    }
    let corr = stats::pearson(&xs, &ys);
    rep.note(&format!(
        "paper: RP 7/15/28/46/71%, throughput 208->133 MB/s; inverse correlation. measured r = {corr:.3}"
    ));
    rep.data = Json::Arr(data);
    rep
}
