//! Fig 15 + Fig 16: HPIO and MPI-Tile-IO.
//!
//! Fig 15 — two concurrent HPIO instances (c-c × c-nc), region size swept
//! 32..256 KB, 32 processes: BB and SSDUP buffer ~100%; SSDUP+ trades
//! <6% throughput for ~15-20% SSD savings.
//!
//! Fig 16 — two concurrent MPI-Tile-IO instances (1-D and 2-D tilings),
//! 16..128 processes: randomness grows with contention; SSDUP+ matches
//! BB's throughput while saving 15-50% of the SSD.

use crate::experiments::common::{f1, pct, run_system, Report, Scale};
use crate::server::SystemKind;
use crate::util::json::Json;
use crate::workload::hpio::paper_mixed;
use crate::workload::mpitileio::paper_pair;

pub fn fig15(scale: Scale) -> Report {
    let mut rep = Report::new("fig15", "HPIO c-c x c-nc, 32 procs: throughput and SSD usage vs region size");
    rep.columns(&[
        "region KB",
        "orangefs",
        "bb",
        "ssdup",
        "ssdup+",
        "ssdup ssd%",
        "ssdup+ ssd%",
        "saved",
    ]);
    let mut data = Vec::new();
    for region_kb in [32i32, 64, 128, 256] {
        let region_sectors = region_kb * 2;
        let w = paper_mixed(region_sectors, 16, scale.gb8());
        let mut row = vec![region_kb.to_string()];
        let mut obj = vec![("region_kb", Json::from(region_kb as i64))];
        let mut ssdup_ratio = 0.0;
        let mut plus_ratio = 0.0;
        for system in SystemKind::ALL {
            let r = run_system(system, &w, scale, |_| {});
            row.push(f1(r.throughput_mbps()));
            obj.push((system.name(), Json::Num(r.throughput_mbps())));
            match system {
                SystemKind::Ssdup => ssdup_ratio = r.ssd_ratio,
                SystemKind::SsdupPlus => plus_ratio = r.ssd_ratio,
                _ => {}
            }
        }
        row.push(pct(ssdup_ratio));
        row.push(pct(plus_ratio));
        row.push(pct((ssdup_ratio - plus_ratio).max(0.0)));
        obj.push(("ssdup_ssd_ratio", Json::Num(ssdup_ratio)));
        obj.push(("ssdup_plus_ssd_ratio", Json::Num(plus_ratio)));
        rep.row(row);
        data.push(Json::obj(obj));
    }
    rep.note("paper: SSDUP+ within 6% of SSDUP/BB throughput, saving 13.6-19.9% SSD");
    rep.data = Json::Arr(data);
    rep
}

pub fn fig16(scale: Scale) -> Report {
    let mut rep = Report::new("fig16", "MPI-Tile-IO pair (1-D x 2-D): throughput and SSD usage vs procs");
    rep.columns(&[
        "procs",
        "orangefs",
        "bb",
        "ssdup",
        "ssdup+",
        "ssdup ssd%",
        "ssdup+ ssd%",
    ]);
    let mut data = Vec::new();
    for procs in [16u32, 32, 64, 128] {
        let w = paper_pair(procs, scale.gb16());
        let mut row = vec![procs.to_string()];
        let mut obj = vec![("procs", Json::from(procs as u64))];
        let mut ssdup_ratio = 0.0;
        let mut plus_ratio = 0.0;
        for system in SystemKind::ALL {
            let r = run_system(system, &w, scale, |_| {});
            row.push(f1(r.throughput_mbps()));
            obj.push((system.name(), Json::Num(r.throughput_mbps())));
            match system {
                SystemKind::Ssdup => ssdup_ratio = r.ssd_ratio,
                SystemKind::SsdupPlus => plus_ratio = r.ssd_ratio,
                _ => {}
            }
        }
        row.push(pct(ssdup_ratio));
        row.push(pct(plus_ratio));
        obj.push(("ssdup_ssd_ratio", Json::Num(ssdup_ratio)));
        obj.push(("ssdup_plus_ssd_ratio", Json::Num(plus_ratio)));
        rep.row(row);
        data.push(Json::obj(obj));
    }
    rep.note("paper: at 32p SSDUP+ buffers 46.87% vs SSDUP 95%; throughput tracks BB throughout");
    rep.data = Json::Arr(data);
    rep
}
