//! Shared experiment infrastructure: report formatting, scaling, arrival
//! synthesis, and the standard paper workload sizes.

use crate::server::{simulate, SimConfig, SimResult, SystemKind};
use crate::types::DEFAULT_REQ_SECTORS;
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::workload::Workload;

/// Experiment scaling: paper sizes divided by `factor` (sim time control;
/// shapes are scale-invariant because the SSD capacity scales alongside).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub factor: u64,
    pub seed: u64,
}

impl Default for Scale {
    fn default() -> Self {
        // /8: 16 GB files simulate as 2 GB — ~8k requests per instance,
        // enough streams for detection statistics at every process count.
        Self { factor: 8, seed: 0x55D0 }
    }
}

impl Scale {
    pub fn quick() -> Self {
        Self { factor: 64, seed: 0x55D0 }
    }

    /// 16 GB (the paper's shared IOR file) scaled, in sectors.
    pub fn gb16(&self) -> i64 {
        (16 * 1024 * 1024 * 1024 / 512) / self.factor as i64
    }

    pub fn gb8(&self) -> i64 {
        self.gb16() / 2
    }

    pub fn gb2(&self) -> i64 {
        self.gb16() / 8
    }

    /// An SSD capacity quoted by the paper (in MiB), scaled.
    pub fn ssd_mib(&self, paper_mib: u64) -> u64 {
        (paper_mib / self.factor).max(8)
    }
}

/// A reproduced table/figure.
#[derive(Clone, Debug)]
pub struct Report {
    pub id: &'static str,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
    pub data: Json,
}

impl Report {
    pub fn new(id: &'static str, title: &str) -> Self {
        Self {
            id,
            title: title.to_string(),
            columns: vec![],
            rows: vec![],
            notes: vec![],
            data: Json::Null,
        }
    }

    pub fn columns(&mut self, cols: &[&str]) -> &mut Self {
        self.columns = cols.iter().map(|c| c.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, n: &str) -> &mut Self {
        self.notes.push(n.to_string());
        self
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                if i >= widths.len() {
                    widths.push(cell.len());
                } else {
                    widths[i] = widths[i].max(cell.len());
                }
            }
        }
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        let hdr: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect();
        out.push_str(&hdr.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(hdr.join("  ").len()));
        out.push('\n');
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
                .collect();
            out.push_str(&line.join("  "));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Run one workload under one system with standard config knobs.
pub fn run_system(
    system: SystemKind,
    workload: &Workload,
    scale: Scale,
    tweak: impl FnOnce(&mut SimConfig),
) -> SimResult {
    let mut cfg = SimConfig::new(system).with_seed(scale.seed);
    tweak(&mut cfg);
    simulate(&cfg, workload)
}

/// Synthesize the *server arrival order* of a workload's requests without
/// running the full simulation: processes issue round-robin with seeded
/// jitter-driven skips. Used by the offset-trace experiments (Fig 3/5/7),
/// which analyze arrival patterns rather than timing.
pub fn synthesize_arrival(workload: &Workload, seed: u64) -> Vec<(i32, i32)> {
    let mut cursors: Vec<usize> = vec![0; workload.processes.len()];
    let mut rng = Prng::new(seed);
    let total = workload.total_requests();
    let mut out = Vec::with_capacity(total);
    let mut live: Vec<usize> = (0..workload.processes.len()).collect();
    while !live.is_empty() {
        // each round, processes fire in a jittered order and some lag a
        // round behind (network/CPU scatter) — without the lag, strided
        // rounds arrive perfectly aligned and sort back to contiguous,
        // which no real server ever sees
        let mut order = live.clone();
        rng.shuffle(&mut order);
        let mut emitted = false;
        for p in order {
            if rng.chance(0.35) {
                continue; // this process lags this round
            }
            let wl = &workload.processes[p];
            if cursors[p] < wl.reqs.len() {
                let r = wl.reqs[cursors[p]];
                out.push((r.offset, r.size));
                cursors[p] += 1;
                emitted = true;
            }
        }
        if !emitted {
            // guarantee progress
            let p = live[rng.range(0, live.len())];
            let r = workload.processes[p].reqs[cursors[p]];
            out.push((r.offset, r.size));
            cursors[p] += 1;
        }
        live.retain(|&p| cursors[p] < workload.processes[p].reqs.len());
    }
    out
}

/// Request size in sectors used across experiments (256 KB).
pub const REQ: i32 = DEFAULT_REQ_SECTORS;

/// Scaled IOR workload whose *offset span* stays at the paper's unscaled
/// file size (randomness is then scale-invariant; see
/// `segmented_random_spanned`).
pub fn ior_w(
    app: u16,
    pattern: crate::workload::ior::IorPattern,
    procs: u32,
    scaled_sectors: i64,
    scale: Scale,
    seed_off: u64,
) -> Workload {
    crate::workload::ior::ior_spanned(
        app,
        pattern,
        procs,
        scaled_sectors,
        scaled_sectors * scale.factor as i64,
        REQ,
        scale.seed + seed_off,
    )
}

/// Format helpers.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ior::{ior, IorPattern};

    #[test]
    fn scale_math() {
        let s = Scale { factor: 8, seed: 0 };
        assert_eq!(s.gb16(), 4 * 1024 * 1024);
        assert_eq!(s.gb8(), 2 * 1024 * 1024);
        assert_eq!(s.ssd_mib(8192), 1024);
    }

    #[test]
    fn report_renders_aligned() {
        let mut r = Report::new("figX", "test");
        r.columns(&["a", "long-column"]);
        r.row(vec!["1".into(), "2".into()]);
        r.note("hello");
        let s = r.render();
        assert!(s.contains("figX"));
        assert!(s.contains("long-column"));
        assert!(s.contains("note: hello"));
    }

    #[test]
    fn synthesized_arrival_is_complete_and_deterministic() {
        let w = ior(0, IorPattern::Strided, 8, 65536, REQ, 1);
        let a = synthesize_arrival(&w, 9);
        let b = synthesize_arrival(&w, 9);
        assert_eq!(a.len(), w.total_requests());
        assert_eq!(a, b);
        let c = synthesize_arrival(&w, 10);
        assert_ne!(a, c, "different seed, different interleaving");
    }
}
