//! Fig 3 + Fig 5: offset-trace analysis of the four access patterns.
//!
//! Fig 3 shows the raw arrival-order offsets of the first 128 requests;
//! Fig 5 shows the random factor after sorting each 128-request stream
//! (RP ≈ 11% contiguous / 100% random / 45% strided / 71.9% mixed).

use crate::detector::native::detect_stream;
use crate::experiments::common::{ior_w, pct, synthesize_arrival, Report, Scale};
use crate::util::json::Json;
use crate::workload::ior::IorPattern;
use crate::workload::Workload;

fn pattern_workloads(scale: Scale, procs: u32) -> Vec<(&'static str, Workload)> {
    let size = scale.gb16().min(131_072 * 16); // traces need ~thousands of reqs only
    let contig = ior_w(0, IorPattern::SegmentedContiguous, procs, size, scale, 0);
    let random = ior_w(0, IorPattern::SegmentedRandom, procs, size, scale, 0);
    let strided = ior_w(0, IorPattern::Strided, procs, size, scale, 0);
    let mixed = Workload::concurrent(
        "mixed",
        ior_w(0, IorPattern::SegmentedContiguous, procs, size / 2, scale, 0),
        ior_w(0, IorPattern::SegmentedRandom, procs, size / 2, scale, 1),
    );
    vec![("seg-contiguous", contig), ("seg-random", random), ("strided", strided), ("mixed", mixed)]
}

pub fn fig3(scale: Scale) -> Report {
    let mut rep = Report::new("fig3", "offset distribution of the first 128 arriving requests");
    rep.columns(&["pattern", "min off", "max off", "monotone runs", "distinct gaps"]);
    let mut data = Vec::new();
    for (name, w) in pattern_workloads(scale, 16) {
        let arrivals = synthesize_arrival(&w, scale.seed);
        let first: Vec<i32> = arrivals.iter().take(128).map(|&(o, _)| o).collect();
        // characterize the trace like the scatter plots do visually:
        // contiguous -> few monotone runs & few distinct gaps; random ->
        // many runs/gaps
        let mut runs = 1usize;
        for w2 in first.windows(2) {
            if w2[1] < w2[0] {
                runs += 1;
            }
        }
        let mut gaps: Vec<i32> = first.windows(2).map(|w2| w2[1] - w2[0]).collect();
        gaps.sort_unstable();
        gaps.dedup();
        rep.row(vec![
            name.to_string(),
            first.iter().min().unwrap().to_string(),
            first.iter().max().unwrap().to_string(),
            runs.to_string(),
            gaps.len().to_string(),
        ]);
        data.push(Json::obj(vec![
            ("pattern", Json::from(name)),
            ("offsets", Json::Arr(first.iter().map(|&o| Json::from(o as i64)).collect())),
        ]));
    }
    rep.note("offsets (sectors) of the synthesized server arrival order; full traces in data");
    rep.data = Json::Arr(data);
    rep
}

pub fn fig5(scale: Scale) -> Report {
    let mut rep =
        Report::new("fig5", "random factor of sorted 128-request streams, by access pattern");
    rep.columns(&["pattern", "S (mean)", "random %", "paper %"]);
    let paper = [("seg-contiguous", 11.0), ("seg-random", 100.0), ("strided", 45.0), ("mixed", 71.9)];
    let mut data = Vec::new();
    for ((name, w), (_, paper_pct)) in pattern_workloads(scale, 16).into_iter().zip(paper) {
        let arrivals = synthesize_arrival(&w, scale.seed);
        let streams: Vec<&[(i32, i32)]> = arrivals.chunks_exact(128).take(32).collect();
        let dets: Vec<_> = streams.iter().map(|s| detect_stream(s)).collect();
        let mean_s = dets.iter().map(|d| d.s as f64).sum::<f64>() / dets.len() as f64;
        let mean_pct = dets.iter().map(|d| d.percentage as f64).sum::<f64>() / dets.len() as f64;
        rep.row(vec![
            name.to_string(),
            format!("{mean_s:.1}"),
            pct(mean_pct),
            format!("{paper_pct:.1}%"),
        ]);
        data.push(Json::obj(vec![
            ("pattern", Json::from(name)),
            ("mean_s", Json::Num(mean_s)),
            ("random_pct", Json::Num(mean_pct)),
            ("paper_pct", Json::Num(paper_pct / 100.0)),
        ]));
    }
    rep.note("ordering must match the paper: random > mixed > strided > contiguous");
    rep.data = Json::Arr(data);
    rep
}
