//! Fig 9: traffic-aware flushing under a mixed load.
//!
//! Two concurrent IOR instances (segmented-contiguous × segmented-random),
//! 8 GB each, SSD region 4 GB (8 GB total per the §2.4.2 micro-benchmark).
//! SSDUP flushes immediately and collides with the contiguous instance's
//! direct HDD writes; SSDUP+ pauses flushing while direct traffic is high.
//! Paper: 90.21/90.48 MB/s vs 67.84/66.15 MB/s (+34.85%), with flush
//! pauses of ~17 s and ~19 s.

use crate::experiments::common::{f1, ior_w, run_system, Report, Scale};
use crate::server::{SimResult, SystemKind};
use crate::util::json::Json;
use crate::workload::ior::IorPattern;
use crate::workload::Workload;

fn mixed_workload(scale: Scale) -> Workload {
    Workload::concurrent(
        "ior-cont+ior-rand",
        ior_w(0, IorPattern::SegmentedContiguous, 16, scale.gb8(), scale, 0),
        ior_w(0, IorPattern::SegmentedRandom, 16, scale.gb8(), scale, 1),
    )
}

fn app_mbps(r: &SimResult, idx: usize) -> f64 {
    r.per_app.get(idx).map(|a| a.throughput_mbps()).unwrap_or(0.0)
}

pub fn fig9(scale: Scale) -> Report {
    let mut rep = Report::new("fig9", "traffic-aware flushing: SSDUP+ vs SSDUP on a mixed load");
    rep.columns(&["system", "IOR1 (cont) MB/s", "IOR2 (rand) MB/s", "flushes", "pause s"]);
    let w = mixed_workload(scale);
    let ssd_mib = scale.ssd_mib(8 * 1024); // two 4 GB regions
    let mut data = Vec::new();
    for system in [SystemKind::Ssdup, SystemKind::SsdupPlus] {
        let r = run_system(system, &w, scale, |c| {
            c.ssd_capacity_sectors = crate::types::mib_to_sectors(ssd_mib);
        });
        let flushes: u64 = r.nodes.iter().map(|n| n.flushes).sum();
        rep.row(vec![
            system.name().to_string(),
            f1(app_mbps(&r, 0)),
            f1(app_mbps(&r, 1)),
            flushes.to_string(),
            f1(r.total_flush_pause_us() as f64 / 1e6),
        ]);
        data.push(Json::obj(vec![
            ("system", Json::from(system.name())),
            ("ior1_mbps", Json::Num(app_mbps(&r, 0))),
            ("ior2_mbps", Json::Num(app_mbps(&r, 1))),
            ("flushes", Json::from(flushes)),
            ("pause_us", Json::from(r.total_flush_pause_us())),
        ]));
    }
    rep.note("paper: SSDUP+ 90.21/90.48 vs SSDUP 67.84/66.15 MB/s (+34.85%); pauses ~17s/~19s");
    rep.data = Json::Arr(data);
    rep
}
