//! Fig 13 + Fig 14: behaviour when the SSD cannot hold the working set.
//!
//! Fig 13 — 8 GB SSD, two workloads of 2×8 GB IOR instances:
//! workload₁ = contiguous × random (flush interferes with direct writes),
//! workload₂ = random × random (everything buffered, immediate flush OK).
//! Paper: SSDUP+ 90.21/90.49 vs BB 73.04/72.71 (+23.98%) vs SSDUP
//! 67.85/66.15 on workload₁; ~equal on workload₂.
//!
//! Fig 14 — two *sequential* random IOR instances with a computing gap
//! 0..30 s between them; SSD = 50% of the data. BB needs the gap to cover
//! its blocking flush; SSDUP+'s pipeline tolerates short gaps (paper:
//! +11.91/10.65/9.92%).

use crate::experiments::common::{f1, ior_w, run_system, Report, Scale};
use crate::server::{SimResult, SystemKind};
use crate::types::mib_to_sectors;
use crate::util::json::Json;
use crate::workload::ior::IorPattern;
use crate::workload::Workload;

fn app_mbps(r: &SimResult, idx: usize) -> f64 {
    r.per_app.get(idx).map(|a| a.throughput_mbps()).unwrap_or(0.0)
}

pub fn fig13(scale: Scale) -> Report {
    let mut rep = Report::new("fig13", "limited SSD (8 GB): per-instance bandwidth");
    rep.columns(&["system", "workload", "inst1 MB/s", "inst2 MB/s"]);
    let w1 = Workload::concurrent(
        "w1: cont x rand",
        ior_w(0, IorPattern::SegmentedContiguous, 16, scale.gb8(), scale, 0),
        ior_w(0, IorPattern::SegmentedRandom, 16, scale.gb8(), scale, 1),
    );
    let w2 = Workload::concurrent(
        "w2: rand x rand",
        ior_w(0, IorPattern::SegmentedRandom, 16, scale.gb8(), scale, 2),
        ior_w(0, IorPattern::SegmentedRandom, 16, scale.gb8(), scale, 3),
    );
    let ssd_sectors = mib_to_sectors(scale.ssd_mib(8 * 1024));
    let mut data = Vec::new();
    for system in [SystemKind::OrangeFsBB, SystemKind::Ssdup, SystemKind::SsdupPlus] {
        for (wname, w) in [("workload1", &w1), ("workload2", &w2)] {
            let r = run_system(system, w, scale, |c| {
                c.ssd_capacity_sectors = ssd_sectors;
            });
            rep.row(vec![
                system.name().to_string(),
                wname.to_string(),
                f1(app_mbps(&r, 0)),
                f1(app_mbps(&r, 1)),
            ]);
            data.push(Json::obj(vec![
                ("system", Json::from(system.name())),
                ("workload", Json::from(wname)),
                ("inst1_mbps", Json::Num(app_mbps(&r, 0))),
                ("inst2_mbps", Json::Num(app_mbps(&r, 1))),
                ("pause_us", Json::from(r.total_flush_pause_us())),
            ]));
        }
    }
    rep.note("paper w1: SSDUP+ 90.2/90.5 > BB 73.0/72.7 > SSDUP 67.9/66.2; w2 roughly system-equal");
    rep.data = Json::Arr(data);
    rep
}

pub fn fig14(scale: Scale) -> Report {
    let mut rep =
        Report::new("fig14", "computing-time gap between two IOR instances (SSD = 50% of data)");
    rep.columns(&["gap s", "orangefs-bb MB/s", "ssdup+ MB/s", "gain"]);
    // each instance 8 GB; per-node SSD 4 GB (paper: BB 4 GB, SSDUP+ 2 x 2 GB)
    let ssd_sectors = mib_to_sectors(scale.ssd_mib(4 * 1024));
    let mut data = Vec::new();
    for gap_s in [0u64, 10, 20, 30] {
        let a = ior_w(0, IorPattern::SegmentedRandom, 16, scale.gb8(), scale, 0);
        let b = ior_w(0, IorPattern::SegmentedRandom, 16, scale.gb8(), scale, 1);
        // the computing gap scales with the data so the overlap fraction
        // is preserved at reduced simulation scale
        let gap_us = gap_s * 1_000_000 / scale.factor;
        let w = Workload::sequential(&format!("2xior gap{gap_s}s"), a, gap_us, b);
        let bb = run_system(SystemKind::OrangeFsBB, &w, scale, |c| {
            c.ssd_capacity_sectors = ssd_sectors;
        });
        let plus = run_system(SystemKind::SsdupPlus, &w, scale, |c| {
            c.ssd_capacity_sectors = ssd_sectors;
        });
        // the paper's metric: aggregate over the apps' own I/O intervals
        // (the gap itself is computation, not I/O)
        let bb_t = (app_mbps(&bb, 0) + app_mbps(&bb, 1)) / 2.0;
        let plus_t = (app_mbps(&plus, 0) + app_mbps(&plus, 1)) / 2.0;
        let gain = plus_t / bb_t - 1.0;
        rep.row(vec![gap_s.to_string(), f1(bb_t), f1(plus_t), format!("{:+.1}%", gain * 100.0)]);
        data.push(Json::obj(vec![
            ("gap_s", Json::from(gap_s)),
            ("bb_mbps", Json::Num(bb_t)),
            ("ssdup_plus_mbps", Json::Num(plus_t)),
            ("gain", Json::Num(gain)),
        ]));
    }
    rep.note("paper: SSDUP+ over BB by 11.91/10.65/9.92%; BB improves as the gap hides its flush");
    rep.data = Json::Arr(data);
    rep
}
