//! Table 1: SSDUP+ overhead — grouping/sorting cost and AVL maintenance
//! cost as the request size shrinks (32 KB..512 KB over a 2 GB
//! segmented-random IOR with a 2 GB SSD, all requests buffered).
//!
//! Group/AVL costs are measured in *wall-clock* time around the actual
//! detector and AVL code inside the simulated server — the same numbers a
//! real deployment would report — while "total time" is the simulated I/O
//! time, so the fractions are conservative upper bounds.

use crate::experiments::common::{f2, run_system, Report, Scale};
use crate::server::SystemKind;
use crate::util::json::Json;
use crate::workload::ior::IorPattern;

pub fn table1(scale: Scale) -> Report {
    let mut rep = Report::new("table1", "system overhead vs request size");
    rep.columns(&["req KB", "total s", "group ms", "avl ms", "overhead %", "avl peak KB"]);
    let total_sectors = scale.gb2();
    let ssd_mib = scale.ssd_mib(2 * 1024);
    let mut data = Vec::new();
    for req_kb in [32i32, 64, 128, 256, 512] {
        let req_sectors = req_kb * 2;
        let span = total_sectors * scale.factor as i64;
        let w = crate::workload::ior::ior_spanned(
            0,
            IorPattern::SegmentedRandom,
            16,
            total_sectors,
            span,
            req_sectors,
            scale.seed,
        );
        let r = run_system(SystemKind::SsdupPlus, &w, scale, |c| {
            c.ssd_capacity_sectors = crate::types::mib_to_sectors(ssd_mib);
        });
        let group_ms: f64 = r.nodes.iter().map(|n| n.group_cost_us).sum::<f64>() / 1e3;
        let avl_ms: f64 = r.nodes.iter().map(|n| n.avl_cost_us).sum::<f64>() / 1e3;
        let total_s = r.makespan_us as f64 / 1e6;
        let overhead = (group_ms + avl_ms) / 1e3 / total_s * 100.0;
        let avl_peak_kb =
            r.nodes.iter().map(|n| n.avl_metadata_peak_bytes).max().unwrap_or(0) / 1024;
        rep.row(vec![
            req_kb.to_string(),
            f2(total_s),
            f2(group_ms),
            f2(avl_ms),
            format!("{overhead:.3}%"),
            avl_peak_kb.to_string(),
        ]);
        data.push(Json::obj(vec![
            ("req_kb", Json::from(req_kb as i64)),
            ("total_s", Json::Num(total_s)),
            ("group_ms", Json::Num(group_ms)),
            ("avl_ms", Json::Num(avl_ms)),
            ("overhead_pct", Json::Num(overhead)),
            ("avl_peak_kb", Json::from(avl_peak_kb)),
        ]));
    }
    rep.note("paper: total 15.5->11.9s, group 29.1->6.1ms, AVL 93.4->9.5ms; overhead 0.13-0.79%");
    rep.note("costs grow as requests shrink (more requests to group and index)");
    rep.data = Json::Arr(data);
    rep
}
