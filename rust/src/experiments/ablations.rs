//! Ablations for the design choices DESIGN.md calls out — each isolates
//! one SSDUP+ mechanism and quantifies what it buys:
//!
//! * `ablation-log`   — log-structured SSD appends vs in-place (random)
//!   SSD writes (§2.5's write-amplification motivation);
//! * `ablation-pipeline` — two-region pipeline vs one blocking region of
//!   the same total capacity (§2.4.1, Eq. 4–6 analysis);
//! * `ablation-threshold` — the adaptive threshold vs a sweep of static
//!   thresholds (what §2.3.2's adaptivity buys over *any* fixed choice).

use crate::buffer::{BufferOutcome, Pipeline, Region};
use crate::device::{Ssd, SsdConfig};
use crate::experiments::common::{f1, ior_w, pct, run_system, Report, Scale};
use crate::redirector::{AdaptivePolicy, RoutePolicy, Watermark, WatermarkPolicy};
use crate::server::SystemKind;
use crate::types::Route;
use crate::util::json::Json;
use crate::workload::ior::IorPattern;
use crate::workload::Workload;

/// §2.5: time to push a random write-set through the SSD, appended
/// (log-structured) vs written in place (amplified).
pub fn ablation_log(_scale: Scale) -> Report {
    let mut rep = Report::new(
        "ablation-log",
        "log-structured appends vs in-place SSD writes (512 MiB random set)",
    );
    rep.columns(&["mode", "ssd busy ms", "effective MB/s"]);
    let n = 4096;
    let sectors = 256;
    let mut data = Vec::new();
    for (mode, append) in [("log-append", true), ("in-place", false)] {
        let mut ssd: Ssd<u32> = Ssd::new(SsdConfig::default());
        for i in 0..n {
            if append {
                ssd.enqueue_append(sectors, i);
            } else {
                ssd.enqueue_random_write(sectors, i);
            }
        }
        let mut now = 0;
        while let Some(d) = ssd.try_dispatch(now) {
            now = d.done_at;
            ssd.complete();
        }
        let mbps = ssd.bytes_written as f64 / ssd.total_busy_us;
        rep.row(vec![mode.to_string(), f1(ssd.total_busy_us / 1e3), f1(mbps)]);
        data.push(Json::obj(vec![
            ("mode", Json::from(mode)),
            ("busy_us", Json::Num(ssd.total_busy_us)),
            ("mbps", Json::Num(mbps)),
        ]));
    }
    rep.note("the log structure should recover the device's full write bandwidth (~2.2x)");
    rep.data = Json::Arr(data);
    rep
}

/// §2.4.1: two-region pipeline vs one region of the same total capacity,
/// under synchronous fill/flush pressure (counts blocked attempts).
pub fn ablation_pipeline(_scale: Scale) -> Report {
    let mut rep = Report::new(
        "ablation-pipeline",
        "two-region pipeline vs single region (same total capacity)",
    );
    rep.columns(&["buffer", "accepted while flushing", "blocked events"]);
    let cap = 8192i64;
    let mut data = Vec::new();

    // single region: everything blocks while the (simulated) flush is out
    {
        let mut region = Region::new(cap);
        let mut accepted = 0u64;
        let mut blocked = 0u64;
        let mut off = 0i64;
        for _ in 0..64 {
            // fill
            while region.buffer(0, off, 256).is_some() {
                off += 256;
            }
            // flush is "in flight": any arrival during it blocks
            for _ in 0..16 {
                blocked += 1; // single region has nowhere to put them
            }
            region.drain_for_flush();
            accepted += cap as u64 / 256;
        }
        rep.row(vec!["single".into(), "0".into(), blocked.to_string()]);
        data.push(Json::obj(vec![
            ("buffer", Json::from("single")),
            ("accepted_while_flushing", Json::from(0u64)),
            ("blocked", Json::from(blocked)),
        ]));
        let _ = accepted;
    }

    // pipeline: the other region absorbs arrivals during a flush
    {
        let mut p = Pipeline::new(cap);
        let mut accepted_during_flush = 0u64;
        let mut blocked = 0u64;
        let mut off = 0i64;
        for _ in 0..64 {
            loop {
                match p.buffer(0, off, 256) {
                    BufferOutcome::Buffered { .. } => {
                        if p.flushing_region().is_some() {
                            accepted_during_flush += 1;
                        }
                        off += 256;
                    }
                    BufferOutcome::BufferedAndFull { .. } => {
                        p.next_flush();
                        off += 256;
                    }
                    BufferOutcome::Blocked => {
                        blocked += 1;
                        if p.flushing_region().is_some() {
                            p.drain_flushing();
                            p.flush_done();
                        } else if p.next_flush().is_none() {
                            break;
                        }
                    }
                }
                if off > 64 * cap {
                    break;
                }
            }
        }
        rep.row(vec!["pipeline".into(), accepted_during_flush.to_string(), blocked.to_string()]);
        data.push(Json::obj(vec![
            ("buffer", Json::from("pipeline")),
            ("accepted_while_flushing", Json::from(accepted_during_flush)),
            ("blocked", Json::from(blocked)),
        ]));
    }
    rep.note("the pipeline keeps absorbing writes during flushes; a single region cannot");
    rep.data = Json::Arr(data);
    rep
}

/// §2.3.2: adaptive threshold vs static thresholds swept 0.2..0.8 on a
/// mixed load — SSD bytes vs throughput trade-off.
pub fn ablation_threshold(scale: Scale) -> Report {
    let mut rep = Report::new(
        "ablation-threshold",
        "adaptive vs static thresholds: SSD volume at matched throughput",
    );
    rep.columns(&["policy", "throughput MB/s", "ssd %"]);
    let w = Workload::concurrent(
        "mixed",
        ior_w(0, IorPattern::SegmentedContiguous, 16, scale.gb8(), scale, 0),
        ior_w(0, IorPattern::SegmentedRandom, 16, scale.gb8(), scale, 1),
    );
    let mut data = Vec::new();
    // static sweep via SSDUP's watermark machinery (high == low == t)
    for t in [0.2f32, 0.35, 0.5, 0.65, 0.8] {
        let r = run_system(SystemKind::Ssdup, &w, scale, |c| {
            c.static_threshold = Some(t);
        });
        rep.row(vec![format!("static {t:.2}"), f1(r.throughput_mbps()), pct(r.ssd_ratio)]);
        data.push(Json::obj(vec![
            ("policy", Json::from(format!("static-{t}"))),
            ("mbps", Json::Num(r.throughput_mbps())),
            ("ssd_ratio", Json::Num(r.ssd_ratio)),
        ]));
    }
    let r = run_system(SystemKind::SsdupPlus, &w, scale, |_| {});
    rep.row(vec!["adaptive".into(), f1(r.throughput_mbps()), pct(r.ssd_ratio)]);
    data.push(Json::obj(vec![
        ("policy", Json::from("adaptive")),
        ("mbps", Json::Num(r.throughput_mbps())),
        ("ssd_ratio", Json::Num(r.ssd_ratio)),
    ]));
    rep.note("adaptive should sit on the static sweep's Pareto frontier without tuning");
    rep.data = Json::Arr(data);
    rep
}

/// Sanity helper used by unit tests: route a fixed detection sequence
/// through both policies.
pub fn policy_ssd_fraction(percentages: &[f32], adaptive: bool) -> f64 {
    let mut a = AdaptivePolicy::default();
    let mut w = WatermarkPolicy::new(Watermark::new(0.45, 0.45));
    let mut ssd = 0usize;
    for &p in percentages {
        let det = crate::types::Detection { s: 0, percentage: p, seek_cost_us: 0.0 };
        let route = if adaptive { a.on_stream(&det) } else { w.on_stream(&det) };
        if route == Route::Ssd {
            ssd += 1;
        }
    }
    ssd as f64 / percentages.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_ablation_shows_write_amp_gap() {
        let rep = ablation_log(Scale::quick());
        let rows = rep.data.as_arr().unwrap();
        let log = rows[0].get("mbps").unwrap().as_f64().unwrap();
        let inplace = rows[1].get("mbps").unwrap().as_f64().unwrap();
        assert!(log > inplace * 1.8, "log {log} vs in-place {inplace}");
    }

    #[test]
    fn pipeline_ablation_absorbs_during_flush() {
        let rep = ablation_pipeline(Scale::quick());
        let rows = rep.data.as_arr().unwrap();
        let single_abs = rows[0].get("accepted_while_flushing").unwrap().as_f64().unwrap();
        let pipe_abs = rows[1].get("accepted_while_flushing").unwrap().as_f64().unwrap();
        assert_eq!(single_abs, 0.0);
        assert!(pipe_abs > 0.0);
    }

    #[test]
    fn policy_fraction_helper() {
        let ps: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let ad = policy_ssd_fraction(&ps, true);
        let st = policy_ssd_fraction(&ps, false);
        assert!(ad > 0.0 && ad < 1.0);
        assert!(st > 0.0 && st < 1.0);
    }
}
