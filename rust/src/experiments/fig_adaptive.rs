//! Fig 7 + Fig 8: the adaptive redirection algorithm.
//!
//! Fig 7 — case study of the PercentList: per-stream percentages, the
//! evolving threshold, and which streams get directed to SSD (paper
//! reports 79.48% "correct" directions over 512 streams).
//!
//! Fig 8 — strided IOR across process counts: SSDUP+ holds throughput with
//! *less* SSD than SSDUP because the adaptive threshold redirects only the
//! genuinely random share (paper: 27.25%/46.68%/65.63% vs SSDUP's
//! 98.73%/99.9%).

use crate::detector::native::detect_stream;
use crate::experiments::common::{f1, ior_w, pct, run_system, synthesize_arrival, Report, Scale, REQ};
use crate::redirector::{AdaptivePolicy, RoutePolicy};
use crate::server::SystemKind;
use crate::types::Route;
use crate::util::json::Json;
use crate::workload::ior::IorPattern;

pub fn fig7(scale: Scale) -> Report {
    let mut rep = Report::new("fig7", "PercentList case study: thresholds and SSD directions");
    rep.columns(&["streams", "to SSD", "to HDD", "correct directions", "final threshold"]);

    // strided IOR with enough requests for ~512 streams of 128
    let w = ior_w(0, IorPattern::Strided, 32, (512 * 128 * REQ as usize) as i64, scale, 0);
    let arrivals = synthesize_arrival(&w, scale.seed);
    let mut policy = AdaptivePolicy::default();
    let mut to_ssd = 0usize;
    let mut correct = 0usize;
    let mut trace = Vec::new();
    let dets: Vec<_> = arrivals.chunks_exact(128).map(detect_stream).collect();
    let avg: f32 = dets.iter().map(|d| d.percentage).sum::<f32>() / dets.len() as f32;
    for det in &dets {
        let route = policy.on_stream(det);
        let thr = policy.threshold().unwrap_or(0.5);
        if route == Route::Ssd {
            to_ssd += 1;
            // the paper's correctness criterion: a stream directed to SSD
            // whose percentage exceeds the average threshold
            if det.percentage > avg {
                correct += 1;
            }
        } else if det.percentage <= avg {
            correct += 1;
        }
        trace.push(Json::obj(vec![
            ("pct", Json::Num(det.percentage as f64)),
            ("threshold", Json::Num(thr as f64)),
            ("route", Json::from(if route == Route::Ssd { "ssd" } else { "hdd" })),
        ]));
    }
    let n = dets.len();
    rep.row(vec![
        n.to_string(),
        to_ssd.to_string(),
        (n - to_ssd).to_string(),
        pct(correct as f64 / n as f64),
        format!("{:.4}", policy.threshold().unwrap_or(0.5)),
    ]);
    rep.note("paper: 512 streams, 79.48% correct directions");
    rep.data = Json::Arr(trace);
    rep
}

pub fn fig8(scale: Scale) -> Report {
    let mut rep = Report::new(
        "fig8",
        "strided IOR: throughput and SSD ratio — OrangeFS vs SSDUP vs SSDUP+",
    );
    rep.columns(&[
        "procs",
        "orangefs MB/s",
        "ssdup MB/s",
        "ssdup+ MB/s",
        "ssdup ssd%",
        "ssdup+ ssd%",
    ]);
    let mut data = Vec::new();
    for procs in [8u32, 16, 32, 64, 128] {
        let w = ior_w(0, IorPattern::Strided, procs, scale.gb16(), scale, 0);
        let native = run_system(SystemKind::OrangeFs, &w, scale, |_| {});
        let ssdup = run_system(SystemKind::Ssdup, &w, scale, |_| {});
        let plus = run_system(SystemKind::SsdupPlus, &w, scale, |_| {});
        rep.row(vec![
            procs.to_string(),
            f1(native.throughput_mbps()),
            f1(ssdup.throughput_mbps()),
            f1(plus.throughput_mbps()),
            pct(ssdup.ssd_ratio),
            pct(plus.ssd_ratio),
        ]);
        data.push(Json::obj(vec![
            ("procs", Json::from(procs as u64)),
            ("orangefs_mbps", Json::Num(native.throughput_mbps())),
            ("ssdup_mbps", Json::Num(ssdup.throughput_mbps())),
            ("ssdup_plus_mbps", Json::Num(plus.throughput_mbps())),
            ("ssdup_ssd_ratio", Json::Num(ssdup.ssd_ratio)),
            ("ssdup_plus_ssd_ratio", Json::Num(plus.ssd_ratio)),
        ]));
    }
    rep.note("paper: SSDUP+ matches SSDUP throughput with far less SSD (e.g. 46.68% vs 98.73% at 64p)");
    rep.data = Json::Arr(data);
    rep
}
