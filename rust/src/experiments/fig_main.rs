//! Fig 11 + Fig 12: the headline IOR evaluation.
//!
//! Fig 11 — all four systems across 8..512 processes and all three access
//! patterns (unconstrained SSD): SSDUP+ tracks OrangeFS-BB's throughput
//! within a few percent while buffering a *fraction* of the data
//! (25%/40%/66%/84.5%/97% as randomness grows).
//!
//! Fig 12 — CFQ queue size 32/128/512 with 32-process strided IOR:
//! smaller queues merge worse, so SSDUP+'s relative gain is largest at 32
//! (paper: +59.7%/+41.5%/+12.3%).

use crate::experiments::common::{f1, ior_w, pct, run_system, Report, Scale};
use crate::server::SystemKind;
use crate::util::json::Json;
use crate::workload::ior::IorPattern;
use crate::workload::Workload;

/// The paper's Fig-11 composite: the three IOR instances run as one mixed
/// workload per process count (each instance gets procs/3 processes, the
/// same shared-file sizes as §4.2).
fn fig11_workload(scale: Scale, procs: u32) -> Workload {
    let p = (procs / 3).max(1);
    let contig = ior_w(0, IorPattern::SegmentedContiguous, p, scale.gb16(), scale, 0);
    let strided = ior_w(0, IorPattern::Strided, p, scale.gb16(), scale, 1);
    let random = ior_w(0, IorPattern::SegmentedRandom, p, scale.gb16() / 2, scale, 2);
    Workload::concurrent(
        &format!("ior-3patterns-p{procs}"),
        Workload::concurrent("cs", contig, strided),
        random,
    )
}

pub fn fig11(scale: Scale) -> Report {
    let mut rep = Report::new(
        "fig11",
        "IOR mixed patterns, 4 systems: throughput and SSD usage vs process count",
    );
    rep.columns(&[
        "procs",
        "orangefs",
        "bb",
        "ssdup",
        "ssdup+",
        "ssdup ssd%",
        "ssdup+ ssd%",
        "bb ssd%",
    ]);
    let mut data = Vec::new();
    for procs in [8u32, 16, 32, 64, 128, 256, 512] {
        let w = fig11_workload(scale, procs);
        let mut row = vec![procs.to_string()];
        let mut obj = vec![("procs", Json::from(procs as u64))];
        let mut ratios = Vec::new();
        for system in SystemKind::ALL {
            let r = run_system(system, &w, scale, |_| {});
            row.push(f1(r.throughput_mbps()));
            obj.push((system.name(), Json::Num(r.throughput_mbps())));
            ratios.push((system, r.ssd_ratio));
        }
        for (system, ratio) in &ratios {
            if matches!(system, SystemKind::Ssdup | SystemKind::SsdupPlus | SystemKind::OrangeFsBB) {
                obj.push((
                    match system {
                        SystemKind::Ssdup => "ssdup_ssd_ratio",
                        SystemKind::SsdupPlus => "ssdup_plus_ssd_ratio",
                        _ => "bb_ssd_ratio",
                    },
                    Json::Num(*ratio),
                ));
            }
        }
        let get = |k: SystemKind| ratios.iter().find(|(s, _)| *s == k).unwrap().1;
        row.push(pct(get(SystemKind::Ssdup)));
        row.push(pct(get(SystemKind::SsdupPlus)));
        row.push(pct(get(SystemKind::OrangeFsBB)));
        rep.row(row);
        data.push(Json::obj(obj));
    }
    rep.note("paper: SSDUP+ within 2.2-5% of BB while buffering 25-97% (vs SSDUP's 41.5-3% more)");
    rep.data = Json::Arr(data);
    rep
}

pub fn fig12(scale: Scale) -> Report {
    let mut rep = Report::new("fig12", "CFQ queue size: OrangeFS vs SSDUP+ (strided, 32 procs)");
    rep.columns(&["queue", "orangefs MB/s", "ssdup+ MB/s", "gain", "ssd%"]);
    let mut data = Vec::new();
    for q in [32usize, 128, 512] {
        let w = ior_w(0, IorPattern::Strided, 32, scale.gb16(), scale, 0);
        let base = run_system(SystemKind::OrangeFs, &w, scale, |c| {
            *c = c.clone().with_queue_size(q);
        });
        let plus = run_system(SystemKind::SsdupPlus, &w, scale, |c| {
            *c = c.clone().with_queue_size(q);
        });
        let gain = plus.throughput_mbps() / base.throughput_mbps() - 1.0;
        rep.row(vec![
            q.to_string(),
            f1(base.throughput_mbps()),
            f1(plus.throughput_mbps()),
            pct(gain),
            pct(plus.ssd_ratio),
        ]);
        data.push(Json::obj(vec![
            ("queue", Json::from(q)),
            ("orangefs_mbps", Json::Num(base.throughput_mbps())),
            ("ssdup_plus_mbps", Json::Num(plus.throughput_mbps())),
            ("gain", Json::Num(gain)),
            ("ssd_ratio", Json::Num(plus.ssd_ratio)),
        ]));
    }
    rep.note("paper: +59.7% at q=32, +41.5% at q=128, +12.3% at q=512 (gain shrinks as CFQ merges better)");
    rep.data = Json::Arr(data);
    rep
}
