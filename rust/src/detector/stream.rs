//! Request-stream grouping (paper §2.1/§2.3.1).
//!
//! The server groups arriving write requests into blocks of `stream_len`
//! (default 128, matching the CFQ queue depth; reconfigure when the queue
//! size changes — Fig 12). Grouping is server-wide across applications:
//! the whole point of server-side detection is seeing the mixed load.

use crate::types::Request;

/// A completed request stream ready for detection.
#[derive(Clone, Debug)]
pub struct StreamRecord {
    /// (offset, size) pairs in sectors, arrival order
    pub reqs: Vec<(i32, i32)>,
    /// distinct applications that contributed
    pub apps: u32,
}

/// Groups requests into fixed-length streams.
#[derive(Clone, Debug)]
pub struct StreamGrouper {
    stream_len: usize,
    buf: Vec<(i32, i32)>,
    app_mask: u64,
    pub streams_emitted: u64,
}

impl StreamGrouper {
    pub fn new(stream_len: usize) -> Self {
        assert!(stream_len >= 2, "stream length must be >= 2");
        Self { stream_len, buf: Vec::with_capacity(stream_len), app_mask: 0, streams_emitted: 0 }
    }

    pub fn stream_len(&self) -> usize {
        self.stream_len
    }

    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Add a request; returns the completed stream when the block fills.
    pub fn push(&mut self, req: &Request) -> Option<StreamRecord> {
        self.push_parts(req.app, req.offset, req.size)
    }

    /// Add a request by raw (app, offset, size) — the server feeds the
    /// post-striping *disk* address here, not the logical file offset.
    pub fn push_parts(&mut self, app: u16, offset: i32, size: i32) -> Option<StreamRecord> {
        self.buf.push((offset, size));
        self.app_mask |= 1u64 << (app as u64 % 64);
        if self.buf.len() == self.stream_len {
            Some(self.take())
        } else {
            None
        }
    }

    /// Flush an incomplete tail block (end of run).
    pub fn flush_partial(&mut self) -> Option<StreamRecord> {
        if self.buf.len() < 2 {
            self.buf.clear();
            self.app_mask = 0;
            return None;
        }
        Some(self.take())
    }

    fn take(&mut self) -> StreamRecord {
        self.streams_emitted += 1;
        let apps = self.app_mask.count_ones();
        self.app_mask = 0;
        StreamRecord { reqs: std::mem::replace(&mut self.buf, Vec::with_capacity(self.stream_len)), apps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(app: u16, offset: i32) -> Request {
        Request { app, proc_id: 0, file: 1, offset, size: 512 }
    }

    #[test]
    fn emits_exactly_at_stream_len() {
        let mut g = StreamGrouper::new(4);
        assert!(g.push(&req(0, 0)).is_none());
        assert!(g.push(&req(0, 512)).is_none());
        assert!(g.push(&req(0, 1024)).is_none());
        let s = g.push(&req(0, 1536)).expect("stream complete");
        assert_eq!(s.reqs.len(), 4);
        assert_eq!(g.pending(), 0);
        assert_eq!(g.streams_emitted, 1);
    }

    #[test]
    fn counts_contributing_apps() {
        let mut g = StreamGrouper::new(3);
        g.push(&req(1, 0));
        g.push(&req(2, 512));
        let s = g.push(&req(1, 1024)).unwrap();
        assert_eq!(s.apps, 2);
        // mask resets for next stream
        g.push(&req(3, 0));
        g.push(&req(3, 1));
        let s2 = g.push(&req(3, 2)).unwrap();
        assert_eq!(s2.apps, 1);
    }

    #[test]
    fn partial_flush_needs_two_requests() {
        let mut g = StreamGrouper::new(128);
        g.push(&req(0, 0));
        assert!(g.flush_partial().is_none(), "singleton dropped");
        g.push(&req(0, 0));
        g.push(&req(0, 512));
        let s = g.flush_partial().unwrap();
        assert_eq!(s.reqs.len(), 2);
    }

    #[test]
    fn preserves_arrival_order() {
        let mut g = StreamGrouper::new(3);
        g.push(&req(0, 30));
        g.push(&req(0, 10));
        let s = g.push(&req(0, 20)).unwrap();
        assert_eq!(s.reqs.iter().map(|r| r.0).collect::<Vec<_>>(), vec![30, 10, 20]);
    }
}
