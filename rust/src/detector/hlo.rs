//! HLO-backed detector: routes stream detection through the AOT-compiled
//! JAX/Pallas module via PJRT. This is the production request path of the
//! three-layer architecture; the native backend mirrors it for the
//! simulator hot loop and for environments without artifacts.
//!
//! The PJRT half ([`HloDetector`]) requires the `pjrt` cargo feature; the
//! [`DetectBackend`] abstraction and the native implementation are always
//! available, and [`default_backend`] picks the best backend this build
//! can offer.

use crate::device::seek::SeekModel;
use crate::types::Detection;

/// Detection backend abstraction so the server can swap native/HLO.
pub trait DetectBackend {
    fn detect(&mut self, reqs: &[(i32, i32)]) -> Detection;
    fn name(&self) -> &'static str;
}

impl DetectBackend for crate::detector::native::NativeDetector {
    fn detect(&mut self, reqs: &[(i32, i32)]) -> Detection {
        crate::detector::native::NativeDetector::detect(self, reqs)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Best-available detection backend: the PJRT/HLO path when this build has
/// the `pjrt` feature and the AOT artifacts are present, otherwise the
/// bit-exact native mirror.
pub fn default_backend(seek: SeekModel) -> Box<dyn DetectBackend> {
    #[cfg(feature = "pjrt")]
    {
        if let Ok(rt) = crate::runtime::Runtime::load_default() {
            if let Ok(exec) = rt.detector() {
                return Box::new(HloDetector::new(exec));
            }
        }
    }
    Box::new(crate::detector::native::NativeDetector::new(seek))
}

#[cfg(feature = "pjrt")]
pub use pjrt_backend::HloDetector;

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use anyhow::Result;

    use super::DetectBackend;
    use crate::runtime::xla_exec::DetectorExec;
    use crate::types::Detection;

    /// PJRT-backed detector. Single streams are padded into the compiled
    /// batch; use [`HloDetector::detect_many`] to amortize the execute call
    /// over up to `batch` streams (the §Perf-preferred shape).
    pub struct HloDetector {
        exec: DetectorExec,
        pub executions: u64,
        pub streams_detected: u64,
    }

    impl HloDetector {
        pub fn new(exec: DetectorExec) -> Self {
            Self { exec, executions: 0, streams_detected: 0 }
        }

        pub fn batch(&self) -> usize {
            self.exec.batch
        }

        pub fn detect_many(&mut self, streams: &[Vec<(i32, i32)>]) -> Result<Vec<Detection>> {
            self.executions += streams.len().div_ceil(self.exec.batch) as u64;
            self.streams_detected += streams.len() as u64;
            self.exec.run_all(streams)
        }
    }

    impl DetectBackend for HloDetector {
        fn detect(&mut self, reqs: &[(i32, i32)]) -> Detection {
            if reqs.len() <= 1 {
                return Detection { s: 0, percentage: 0.0, seek_cost_us: 0.0 };
            }
            self.executions += 1;
            self.streams_detected += 1;
            self.exec
                .run_batch(&[reqs])
                .expect("PJRT detector execution failed")
                .pop()
                .expect("one detection per stream")
        }

        fn name(&self) -> &'static str {
            "hlo"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_backend_detects() {
        let mut b = default_backend(SeekModel::default());
        let contiguous: Vec<(i32, i32)> = (0..64).map(|i| (i * 512, 512)).collect();
        let random: Vec<(i32, i32)> = (0..64).map(|i| (i * 99_991, 512)).collect();
        assert_eq!(b.detect(&contiguous).s, 0);
        assert_eq!(b.detect(&random).s, 63);
        assert!(!b.name().is_empty());
    }
}
