//! HLO-backed detector: routes stream detection through the AOT-compiled
//! JAX/Pallas module via PJRT. This is the production request path of the
//! three-layer architecture; the native backend mirrors it for the
//! simulator hot loop and for environments without artifacts.

use anyhow::Result;

use crate::runtime::xla_exec::DetectorExec;
use crate::types::Detection;

/// Detection backend abstraction so the server can swap native/HLO.
pub trait DetectBackend {
    fn detect(&mut self, reqs: &[(i32, i32)]) -> Detection;
    fn name(&self) -> &'static str;
}

impl DetectBackend for crate::detector::native::NativeDetector {
    fn detect(&mut self, reqs: &[(i32, i32)]) -> Detection {
        crate::detector::native::NativeDetector::detect(self, reqs)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// PJRT-backed detector. Single streams are padded into the compiled
/// batch; use [`HloDetector::detect_many`] to amortize the execute call
/// over up to `batch` streams (the §Perf-preferred shape).
pub struct HloDetector {
    exec: DetectorExec,
    pub executions: u64,
    pub streams_detected: u64,
}

impl HloDetector {
    pub fn new(exec: DetectorExec) -> Self {
        Self { exec, executions: 0, streams_detected: 0 }
    }

    pub fn batch(&self) -> usize {
        self.exec.batch
    }

    pub fn detect_many(&mut self, streams: &[Vec<(i32, i32)>]) -> Result<Vec<Detection>> {
        self.executions += streams.len().div_ceil(self.exec.batch) as u64;
        self.streams_detected += streams.len() as u64;
        self.exec.run_all(streams)
    }
}

impl DetectBackend for HloDetector {
    fn detect(&mut self, reqs: &[(i32, i32)]) -> Detection {
        if reqs.len() <= 1 {
            return Detection { s: 0, percentage: 0.0, seek_cost_us: 0.0 };
        }
        self.executions += 1;
        self.streams_detected += 1;
        self.exec
            .run_batch(&[reqs])
            .expect("PJRT detector execution failed")
            .pop()
            .expect("one detection per stream")
    }

    fn name(&self) -> &'static str {
        "hlo"
    }
}
