//! Native (pure-Rust) detector backend — a bit-exact mirror of the
//! JAX/Pallas kernels in python/compile/kernels/.
//!
//! Mirroring notes: S is integer and must match exactly; `percentage` is
//! computed in f32 exactly as the kernel does; `seek_cost_us` accumulates
//! per-pair f32 costs (the XLA reduce may re-associate, so cross-checks
//! use a small tolerance there).

use crate::device::seek::SeekModel;
use crate::types::Detection;

/// Reusable scratch so the hot loop performs no allocation per stream.
#[derive(Clone, Debug, Default)]
pub struct NativeDetector {
    scratch: Vec<(i32, i32)>,
    pub seek: SeekModel,
}

impl NativeDetector {
    pub fn new(seek: SeekModel) -> Self {
        Self { scratch: Vec::with_capacity(512), seek }
    }

    /// Detect one stream of (offset, size) pairs, both in sectors.
    pub fn detect(&mut self, reqs: &[(i32, i32)]) -> Detection {
        let n = reqs.len();
        if n <= 1 {
            return Detection { s: 0, percentage: 0.0, seek_cost_us: 0.0 };
        }
        self.scratch.clear();
        self.scratch.extend_from_slice(reqs);
        // stable sort by offset: matches jnp.argsort(..., stable=True)
        self.scratch.sort_by_key(|&(off, _)| off);

        let mut s = 0i32;
        let mut cost = 0f32;
        for w in self.scratch.windows(2) {
            let (off_a, size_a) = w[0];
            let (off_b, _) = w[1];
            let gap = off_b.wrapping_sub(off_a);
            if gap != size_a {
                s += 1;
                cost += seek_cost_f32(&self.seek, (gap as i64 - size_a as i64).unsigned_abs());
            }
        }
        let percentage = s as f32 / (n as f32 - 1.0);
        Detection { s, percentage, seek_cost_us: cost }
    }
}

/// f32 evaluation of the seek model — must match the Pallas kernel math.
#[inline]
fn seek_cost_f32(m: &SeekModel, dist: u64) -> f32 {
    let d = dist as f32;
    if dist <= m.knee_sectors as u64 {
        m.short_base_us as f32 + m.short_us_per_sector as f32 * d
    } else {
        let capped = d.min(m.cap_sectors as f32);
        m.long_base_us as f32 + m.long_us_per_sector as f32 * capped
    }
}

/// Convenience one-shot API.
pub fn detect_stream(reqs: &[(i32, i32)]) -> Detection {
    NativeDetector::new(SeekModel::default()).detect(reqs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::quickcheck::forall;

    #[test]
    fn empty_and_singleton() {
        assert_eq!(detect_stream(&[]).s, 0);
        assert_eq!(detect_stream(&[(100, 8)]).percentage, 0.0);
    }

    #[test]
    fn contiguous_is_zero_even_out_of_order() {
        // offsets 0..8*512 step 512, arrival scrambled
        let mut reqs: Vec<(i32, i32)> = (0..8).map(|i| (i * 512, 512)).collect();
        reqs.swap(0, 5);
        reqs.swap(2, 7);
        let d = detect_stream(&reqs);
        assert_eq!(d.s, 0);
        assert_eq!(d.percentage, 0.0);
        assert_eq!(d.seek_cost_us, 0.0);
    }

    #[test]
    fn fully_random_is_n_minus_1() {
        let reqs: Vec<(i32, i32)> = (0..128).map(|i| (i * 10_000, 512)).collect();
        let d = detect_stream(&reqs);
        assert_eq!(d.s, 127);
        assert!((d.percentage - 1.0).abs() < 1e-6);
        assert!(d.seek_cost_us > 0.0);
    }

    #[test]
    fn paper_fig4_example_semantics() {
        // items #2,#3 adjacent after sort -> RF 0; #4 -> #7 gap -> RF 1
        let req = 512;
        let reqs = vec![
            (2 * req, req), // #2
            (4 * req, req), // #4
            (3 * req, req), // #3
            (7 * req, req), // #7
        ];
        let d = detect_stream(&reqs);
        // sorted: 2,3,4,7 -> gaps: (3-2)=req ok, (4-3)=req ok, (7-4)!=req
        assert_eq!(d.s, 1);
    }

    #[test]
    fn percentage_bounds_property() {
        forall(11, 300, "0 <= percentage <= 1", |rng: &mut Prng, size| {
            let n = rng.range(2, 2 + size * 8);
            (0..n)
                .map(|_| (rng.gen_range(1 << 24) as i32, 1 + rng.gen_range(4096) as i32))
                .collect::<Vec<_>>()
        }, |reqs| {
            let d = detect_stream(reqs);
            d.s >= 0 && d.s <= (reqs.len() as i32 - 1) && (0.0..=1.0).contains(&d.percentage)
        });
    }

    #[test]
    fn detection_is_arrival_order_invariant() {
        forall(13, 200, "detect(perm(x)) == detect(x)", |rng: &mut Prng, size| {
            let n = rng.range(2, 2 + size * 4);
            let reqs: Vec<(i32, i32)> = (0..n)
                .map(|_| (rng.gen_range(1 << 20) as i32 * 8, 1 + rng.gen_range(1024) as i32))
                .collect();
            let mut shuffled = reqs.clone();
            rng.shuffle(&mut shuffled);
            (reqs, shuffled)
        }, |(a, b)| {
            let da = detect_stream(a);
            let db = detect_stream(b);
            // S must match exactly; cost can differ in f32 rounding only
            // when duplicate offsets reorder same-offset sizes, so compare
            // with a tolerance.
            da.s == db.s && (da.seek_cost_us - db.seek_cost_us).abs() <= 1.0
        });
    }

    #[test]
    fn no_allocation_reuse_is_consistent() {
        let mut det = NativeDetector::new(SeekModel::default());
        let a: Vec<(i32, i32)> = (0..64).map(|i| (i * 512, 512)).collect();
        let b: Vec<(i32, i32)> = (0..64).map(|i| (i * 99_991, 512)).collect();
        let d1 = det.detect(&a);
        let d2 = det.detect(&b);
        let d1_again = det.detect(&a);
        assert_eq!(d1, d1_again);
        assert!(d2.s > d1.s);
    }
}
