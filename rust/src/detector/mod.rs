//! Random-access detection (paper §2.2).
//!
//! Requests are grouped into fixed-length streams; each completed stream
//! is sorted by offset and scored with the *random factor* metric. Two
//! interchangeable backends compute the score:
//!
//! * [`native`] — pure-Rust mirror of the math (used by the simulator hot
//!   loop and as a fallback when artifacts are absent);
//! * [`hlo`] — the AOT-compiled JAX/Pallas module executed via PJRT
//!   (the three-layer architecture's L1/L2). Integration tests assert the
//!   two agree bit-for-bit on S and to float tolerance on the rest.

pub mod hlo;
pub mod native;
pub mod stream;

pub use native::detect_stream;
pub use stream::{StreamGrouper, StreamRecord};
