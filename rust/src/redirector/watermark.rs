//! Static high/low-water-mark thresholds — SSDUP's (ICS'17) scheme, kept
//! as the baseline the adaptive algorithm is evaluated against.

/// Hysteresis pair: above `high` -> random (SSD); below `low` ->
/// sequential (HDD); in between -> keep the current direction.
#[derive(Clone, Copy, Debug)]
pub struct Watermark {
    pub high: f32,
    pub low: f32,
}

impl Default for Watermark {
    fn default() -> Self {
        // the paper's prototype values: 45% / 30%
        Self { high: 0.45, low: 0.30 }
    }
}

impl Watermark {
    pub fn new(high: f32, low: f32) -> Self {
        assert!(low <= high, "low {low} > high {high}");
        Self { high, low }
    }

    /// Decide given the current direction (true = SSD).
    pub fn decide(&self, percentage: f32, currently_ssd: bool) -> bool {
        if percentage > self.high {
            true
        } else if percentage < self.low {
            false
        } else {
            currently_ssd
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn switches_above_high() {
        let w = Watermark::default();
        assert!(w.decide(0.5, false));
        assert!(w.decide(0.46, false));
    }

    #[test]
    fn switches_below_low() {
        let w = Watermark::default();
        assert!(!w.decide(0.2, true));
    }

    #[test]
    fn hysteresis_band_keeps_direction() {
        let w = Watermark::default();
        assert!(w.decide(0.4, true), "stay SSD inside band");
        assert!(!w.decide(0.4, false), "stay HDD inside band");
    }

    #[test]
    #[should_panic(expected = "low")]
    fn rejects_inverted_marks() {
        Watermark::new(0.2, 0.8);
    }
}
