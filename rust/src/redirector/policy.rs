//! Routing policies (paper Algorithm 1 + baselines).
//!
//! A policy consumes each completed stream's `Detection` and yields the
//! route for the *upcoming* requests — detection always steers the future,
//! which works because HPC access patterns are stable or change smoothly
//! (§2.3.2). Four policies cover the paper's four systems:
//!
//! | policy            | system          |
//! |-------------------|-----------------|
//! | `AlwaysHdd`       | native OrangeFS |
//! | `AlwaysSsd`       | OrangeFS-BB     |
//! | `WatermarkPolicy` | SSDUP           |
//! | `AdaptivePolicy`  | SSDUP+          |

use crate::redirector::adaptive::PercentList;
use crate::redirector::watermark::Watermark;
use crate::types::{Detection, Route};

/// Stream-level routing policy.
pub trait RoutePolicy {
    /// Observe a completed stream; return the route for upcoming requests.
    fn on_stream(&mut self, det: &Detection) -> Route;

    /// Route before any stream has completed.
    fn initial_route(&self) -> Route {
        Route::Hdd
    }

    /// Most recent stream's randomness estimate (for the traffic-aware
    /// flusher); policies that don't track it return None.
    fn current_percentage(&self) -> Option<f32> {
        None
    }

    /// Notify of a workload change (job arrival/departure) — adaptive
    /// policies clear their history (paper §2.3.2).
    fn on_workload_change(&mut self) {}

    fn name(&self) -> &'static str;
}

/// Native OrangeFS: everything to HDD.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlwaysHdd;

impl RoutePolicy for AlwaysHdd {
    fn on_stream(&mut self, _det: &Detection) -> Route {
        Route::Hdd
    }

    fn name(&self) -> &'static str {
        "orangefs"
    }
}

/// OrangeFS-BB: everything to SSD.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlwaysSsd;

impl RoutePolicy for AlwaysSsd {
    fn on_stream(&mut self, _det: &Detection) -> Route {
        Route::Ssd
    }

    fn initial_route(&self) -> Route {
        Route::Ssd
    }

    fn name(&self) -> &'static str {
        "orangefs-bb"
    }
}

/// SSDUP: static 45/30 water marks with hysteresis.
#[derive(Clone, Debug)]
pub struct WatermarkPolicy {
    marks: Watermark,
    current: Route,
    last_pct: Option<f32>,
}

impl Default for WatermarkPolicy {
    fn default() -> Self {
        Self::new(Watermark::default())
    }
}

impl WatermarkPolicy {
    pub fn new(marks: Watermark) -> Self {
        Self { marks, current: Route::Hdd, last_pct: None }
    }
}

impl RoutePolicy for WatermarkPolicy {
    fn on_stream(&mut self, det: &Detection) -> Route {
        self.last_pct = Some(det.percentage);
        let ssd = self.marks.decide(det.percentage, self.current == Route::Ssd);
        self.current = if ssd { Route::Ssd } else { Route::Hdd };
        self.current
    }

    fn current_percentage(&self) -> Option<f32> {
        self.last_pct
    }

    fn name(&self) -> &'static str {
        "ssdup"
    }
}

/// SSDUP+: adaptive PercentList threshold (Algorithm 1).
///
/// Implementation note: the route decision for stream *k* uses the
/// threshold derived from streams 1..k-1 (bootstrap 0.5 — the first
/// threshold the paper's §2.3.2 case study reports), and the stream's
/// percentage is inserted afterwards. Deciding against the post-insert
/// threshold would make perfectly uniform loads (e.g. segmented-random,
/// where every stream scores exactly 1.0) compare `p > p` and never
/// redirect — contradicting Fig 11.
#[derive(Clone, Debug)]
pub struct AdaptivePolicy {
    list: PercentList,
    current: Route,
    last_pct: Option<f32>,
    pub redirected_streams: u64,
    pub total_streams: u64,
}

/// Threshold used before any history exists.
pub const BOOTSTRAP_THRESHOLD: f32 = 0.5;

impl Default for AdaptivePolicy {
    fn default() -> Self {
        Self::new(64)
    }
}

impl AdaptivePolicy {
    pub fn new(history: usize) -> Self {
        Self {
            list: PercentList::new(history),
            current: Route::Hdd,
            last_pct: None,
            redirected_streams: 0,
            total_streams: 0,
        }
    }

    pub fn threshold(&self) -> Option<f32> {
        self.list.threshold()
    }
}

impl RoutePolicy for AdaptivePolicy {
    fn on_stream(&mut self, det: &Detection) -> Route {
        self.total_streams += 1;
        self.last_pct = Some(det.percentage);
        // Algorithm 1, decide-then-insert (see struct docs).
        let threshold = self.list.threshold().unwrap_or(BOOTSTRAP_THRESHOLD);
        match self.current {
            Route::Hdd if det.percentage > threshold => self.current = Route::Ssd,
            Route::Ssd if det.percentage < threshold => self.current = Route::Hdd,
            _ => {}
        }
        self.list.insert(det.percentage);
        if self.current == Route::Ssd {
            self.redirected_streams += 1;
        }
        self.current
    }

    fn current_percentage(&self) -> Option<f32> {
        self.last_pct
    }

    fn on_workload_change(&mut self) {
        self.list.clear();
    }

    fn name(&self) -> &'static str {
        "ssdup+"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(p: f32) -> Detection {
        Detection { s: 0, percentage: p, seek_cost_us: 0.0 }
    }

    #[test]
    fn baselines_are_constant() {
        let mut h = AlwaysHdd;
        let mut s = AlwaysSsd;
        for p in [0.0, 0.5, 1.0] {
            assert_eq!(h.on_stream(&det(p)), Route::Hdd);
            assert_eq!(s.on_stream(&det(p)), Route::Ssd);
        }
        assert_eq!(AlwaysSsd.initial_route(), Route::Ssd);
        assert_eq!(AlwaysHdd.initial_route(), Route::Hdd);
    }

    #[test]
    fn watermark_hysteresis_transition_sequence() {
        let mut p = WatermarkPolicy::default();
        assert_eq!(p.on_stream(&det(0.5)), Route::Ssd, "above high");
        assert_eq!(p.on_stream(&det(0.35)), Route::Ssd, "in band, stay");
        assert_eq!(p.on_stream(&det(0.2)), Route::Hdd, "below low");
        assert_eq!(p.on_stream(&det(0.35)), Route::Hdd, "in band, stay");
    }

    #[test]
    fn adaptive_routes_random_streams_to_ssd() {
        let mut p = AdaptivePolicy::default();
        // stable low-randomness phase
        for _ in 0..10 {
            assert_eq!(p.on_stream(&det(0.1)), Route::Hdd);
        }
        // randomness ramps up -> must eventually cross to SSD
        let mut crossed = false;
        for i in 0..10 {
            let r = p.on_stream(&det(0.5 + 0.05 * i as f32));
            crossed |= r == Route::Ssd;
        }
        assert!(crossed, "high-randomness streams must reach SSD");
        // and back down again
        let mut back = false;
        for _ in 0..20 {
            back |= p.on_stream(&det(0.05)) == Route::Hdd;
        }
        assert!(back, "low-randomness streams must return to HDD");
    }

    #[test]
    fn adaptive_tracks_redirection_stats() {
        let mut p = AdaptivePolicy::default();
        for _ in 0..4 {
            p.on_stream(&det(0.9));
        }
        assert_eq!(p.total_streams, 4);
        assert!(p.redirected_streams >= 3, "all-random load mostly redirected");
        assert_eq!(p.current_percentage(), Some(0.9));
    }

    #[test]
    fn workload_change_clears_adaptive_history() {
        let mut p = AdaptivePolicy::default();
        for _ in 0..8 {
            p.on_stream(&det(0.9));
        }
        p.on_workload_change();
        assert!(p.threshold().is_none());
    }

    #[test]
    fn paper_case_study_direction_rate() {
        // §2.3.2: with the 10 recorded percentages, the streams directed
        // to SSD are the high ones; sanity-check the mechanism yields a
        // majority of "correct" directions (percentage > avg when SSD).
        let seq = [0.3937, 0.5433, 0.5905, 0.6299, 0.6062, 0.5826, 0.622, 0.622, 0.622, 0.6771];
        let mut p = AdaptivePolicy::default();
        let mut to_ssd = Vec::new();
        for v in seq {
            if p.on_stream(&det(v)) == Route::Ssd {
                to_ssd.push(v);
            }
        }
        assert!(!to_ssd.is_empty());
        let avg: f32 = seq.iter().sum::<f32>() / seq.len() as f32;
        let correct = to_ssd.iter().filter(|&&v| v > avg).count();
        assert!(correct * 2 >= to_ssd.len(), "majority of SSD directions are correct");
    }
}
