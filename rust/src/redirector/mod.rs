//! Data redirection (paper §2.3): decide, per request stream, whether the
//! *next* stream's requests go to SSD or HDD.

pub mod adaptive;
pub mod policy;
pub mod watermark;

pub use adaptive::PercentList;
pub use policy::{AdaptivePolicy, AlwaysHdd, AlwaysSsd, RoutePolicy, WatermarkPolicy};
pub use watermark::Watermark;
