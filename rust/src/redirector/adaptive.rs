//! Adaptive threshold over a sorted PercentList (paper §2.3.2, Eq. 2/3).
//!
//! Every completed stream's random percentage is inserted (sorted
//! ascending); the threshold is the element at index
//! `floor((1 - avgper) * (N - 1))`: a history of low percentages selects a
//! high-index (permissive) element so fewer streams go to SSD, a history
//! of high percentages selects a low-index (aggressive) one. The list is
//! cleared when the workload's access pattern changes so old jobs do not
//! steer new ones.

/// Sorted sliding window of recent stream percentages.
#[derive(Clone, Debug)]
pub struct PercentList {
    vals: Vec<f32>,
    cap: usize,
    sum: f64,
}

impl PercentList {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1);
        Self { vals: Vec::with_capacity(cap), cap, sum: 0.0 }
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    pub fn values(&self) -> &[f32] {
        &self.vals
    }

    /// Insert keeping ascending order; evicts the oldest *extreme* — we
    /// drop from whichever end keeps the window centered on recent mass
    /// (classic sliding-sorted-window compromise: the paper never states
    /// an eviction rule; its case study uses a 10-entry history).
    pub fn insert(&mut self, p: f32) {
        let p = p.clamp(0.0, 1.0);
        if self.vals.len() == self.cap {
            // evict the element farthest from the incoming value so the
            // window tracks the current regime
            let lo_dist = (p - self.vals[0]).abs();
            let hi_dist = (p - *self.vals.last().unwrap()).abs();
            let evicted = if lo_dist > hi_dist { self.vals.remove(0) } else { self.vals.pop().unwrap() };
            self.sum -= evicted as f64;
        }
        let idx = self.vals.partition_point(|&v| v <= p);
        self.vals.insert(idx, p);
        self.sum += p as f64;
    }

    /// Average percentage (Eq. 3).
    pub fn avgper(&self) -> f32 {
        if self.vals.is_empty() {
            0.0
        } else {
            (self.sum / self.vals.len() as f64) as f32
        }
    }

    /// Threshold (Eq. 2). None until any history exists.
    pub fn threshold(&self) -> Option<f32> {
        if self.vals.is_empty() {
            return None;
        }
        let n = self.vals.len();
        let avg = self.avgper();
        let idx = ((1.0 - avg) * (n as f32 - 1.0)).floor() as usize;
        Some(self.vals[idx.min(n - 1)])
    }

    /// Workload change detected -> forget history (paper §2.3.2).
    pub fn clear(&mut self) {
        self.vals.clear();
        self.sum = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::quickcheck::forall;

    #[test]
    fn threshold_is_member_and_in_range() {
        forall(5, 300, "threshold ∈ list", |rng: &mut Prng, size| {
            let n = rng.range(1, 2 + size);
            (0..n).map(|_| rng.f64() as f32).collect::<Vec<f32>>()
        }, |ps| {
            let mut l = PercentList::new(64);
            for &p in ps {
                l.insert(p);
            }
            let t = l.threshold().unwrap();
            l.values().contains(&t)
        });
    }

    #[test]
    fn low_history_selects_high_element() {
        let mut l = PercentList::new(64);
        for p in [0.05, 0.08, 0.1, 0.12, 0.15] {
            l.insert(p);
        }
        // avg ~0.1 -> idx floor(0.9*4)=3 -> 0.12
        assert_eq!(l.threshold(), Some(0.12));
    }

    #[test]
    fn high_history_selects_low_element() {
        let mut l = PercentList::new(64);
        for p in [0.85, 0.88, 0.9, 0.92, 0.95] {
            l.insert(p);
        }
        // avg ~0.9 -> idx floor(0.1*4)=0 -> 0.85
        assert_eq!(l.threshold(), Some(0.85));
    }

    #[test]
    fn paper_case_study_thresholds_floor_eq2() {
        // §2.3.2: 10 recorded percentages; we pin the literal Eq. 2
        // (floor) trace — EXPERIMENTS.md discusses the paper's
        // floor/round inconsistency.
        let seq = [0.3937, 0.5433, 0.5905, 0.6299, 0.6062, 0.5826, 0.622, 0.622, 0.622, 0.6771];
        let mut l = PercentList::new(64);
        let mut got = Vec::new();
        for p in seq {
            l.insert(p);
            got.push(l.threshold().unwrap());
        }
        let want = [
            0.3937, 0.3937, 0.3937, 0.5433, 0.5433, 0.5826, 0.5826, 0.5826, 0.5905, 0.5905,
        ];
        for (g, w) in got.iter().zip(want) {
            assert!((g - w).abs() < 1e-6, "got {got:?}");
        }
    }

    #[test]
    fn clear_resets_history() {
        let mut l = PercentList::new(8);
        l.insert(0.9);
        l.clear();
        assert!(l.threshold().is_none());
        assert_eq!(l.avgper(), 0.0);
    }

    #[test]
    fn bounded_capacity_evicts() {
        let mut l = PercentList::new(4);
        for i in 0..100 {
            l.insert(i as f32 / 100.0);
        }
        assert_eq!(l.len(), 4);
        // values stay sorted
        let v = l.values();
        assert!(v.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn avgper_matches_values() {
        let mut l = PercentList::new(16);
        for p in [0.2, 0.4, 0.6] {
            l.insert(p);
        }
        assert!((l.avgper() - 0.4).abs() < 1e-6);
    }
}
