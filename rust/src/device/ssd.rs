//! SSD model: near-zero seek, append-friendly writes, write-amplification
//! penalty for random (non-append) writes when the device fills up —
//! the §2.5 motivation for SSDUP+'s log-structured buffering.

use crate::types::{sectors_to_bytes, Usec};

#[derive(Clone, Copy, Debug)]
pub struct SsdConfig {
    /// sequential/append write bandwidth, MB/s (Intel DC S3520-class)
    pub write_mbps: f64,
    /// read bandwidth (flush path reads the buffered data back), MB/s
    pub read_mbps: f64,
    /// per-request overhead, us (NOOP scheduler: no reordering, tiny cost)
    pub per_io_us: f64,
    /// multiplier >= 1 applied to *non-append* writes: write amplification
    /// when the FTL must garbage-collect (paper §2.5, RIPQ [27])
    pub random_write_amp: f64,
}

impl Default for SsdConfig {
    fn default() -> Self {
        Self { write_mbps: 380.0, read_mbps: 450.0, per_io_us: 8.0, random_write_amp: 2.2 }
    }
}

/// One in-flight SSD operation's completion descriptor.
#[derive(Clone, Debug)]
pub struct SsdDispatch<T> {
    pub done_at: Usec,
    pub tags: Vec<T>,
}

#[derive(Clone, Copy, Debug)]
enum Op {
    AppendWrite,
    RandomWrite,
    Read,
}

#[derive(Clone, Copy, Debug)]
struct QueuedIo<T> {
    sectors: i64,
    op: Op,
    tag: T,
}

/// Simulated SSD (NOOP queue: FIFO service, batched while busy).
pub struct Ssd<T> {
    pub cfg: SsdConfig,
    busy: bool,
    queue: std::collections::VecDeque<QueuedIo<T>>,
    pub bytes_written: u64,
    pub bytes_read: u64,
    pub total_busy_us: f64,
}

impl<T: Copy> Ssd<T> {
    pub fn new(cfg: SsdConfig) -> Self {
        Self {
            cfg,
            busy: false,
            queue: std::collections::VecDeque::new(),
            bytes_written: 0,
            bytes_read: 0,
            total_busy_us: 0.0,
        }
    }

    pub fn is_busy(&self) -> bool {
        self.busy
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Log-structured append (SSDUP+ buffering path).
    pub fn enqueue_append(&mut self, sectors: i64, tag: T) {
        debug_assert!(sectors > 0);
        self.queue.push_back(QueuedIo { sectors, op: Op::AppendWrite, tag });
    }

    /// In-place / random write (what a non-log-structured buffer does).
    pub fn enqueue_random_write(&mut self, sectors: i64, tag: T) {
        debug_assert!(sectors > 0);
        self.queue.push_back(QueuedIo { sectors, op: Op::RandomWrite, tag });
    }

    /// Read buffered data back (flush path).
    pub fn enqueue_read(&mut self, sectors: i64, tag: T) {
        debug_assert!(sectors > 0);
        self.queue.push_back(QueuedIo { sectors, op: Op::Read, tag });
    }

    /// FIFO batch dispatch of everything queued (NOOP semantics).
    pub fn try_dispatch(&mut self, now: Usec) -> Option<SsdDispatch<T>> {
        if self.busy || self.queue.is_empty() {
            return None;
        }
        let mut service_us = 0.0;
        let mut tags = Vec::with_capacity(self.queue.len());
        for io in self.queue.drain(..) {
            let bytes = sectors_to_bytes(io.sectors);
            let us = match io.op {
                Op::AppendWrite => {
                    self.bytes_written += bytes;
                    bytes as f64 / self.cfg.write_mbps
                }
                Op::RandomWrite => {
                    self.bytes_written += bytes;
                    bytes as f64 / self.cfg.write_mbps * self.cfg.random_write_amp
                }
                Op::Read => {
                    self.bytes_read += bytes;
                    bytes as f64 / self.cfg.read_mbps
                }
            };
            service_us += us + self.cfg.per_io_us;
            tags.push(io.tag);
        }
        self.busy = true;
        self.total_busy_us += service_us;
        Some(SsdDispatch { done_at: now + service_us.ceil() as Usec, tags })
    }

    pub fn complete(&mut self) {
        debug_assert!(self.busy, "complete() without dispatch");
        self.busy = false;
    }

    pub fn achieved_write_mbps(&self) -> f64 {
        if self.total_busy_us == 0.0 {
            0.0
        } else {
            self.bytes_written as f64 / self.total_busy_us
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_faster_than_random_write() {
        let mut a = Ssd::<u32>::new(SsdConfig::default());
        let mut r = Ssd::<u32>::new(SsdConfig::default());
        for i in 0..64 {
            a.enqueue_append(512, i);
            r.enqueue_random_write(512, i);
        }
        let da = a.try_dispatch(0).unwrap();
        let dr = r.try_dispatch(0).unwrap();
        assert!(
            (dr.done_at as f64) > (da.done_at as f64) * 1.8,
            "write-amp should make random writes ~2.2x slower: {} vs {}",
            dr.done_at,
            da.done_at
        );
    }

    #[test]
    fn ssd_much_faster_than_hdd_for_random() {
        use crate::device::hdd::{Hdd, HddConfig};
        let mut ssd = Ssd::<u32>::new(SsdConfig::default());
        let mut hdd = Hdd::<u32>::new(HddConfig::default());
        let mut lba = 0i64;
        for i in 0..64 {
            lba += 3_000_000;
            ssd.enqueue_append(512, i);
            hdd.enqueue(lba, 512, 0, i);
        }
        let ds = ssd.try_dispatch(0).unwrap();
        ssd.complete();
        let mut now = 0;
        loop {
            if let Some(d) = hdd.try_dispatch(now) {
                now = d.done_at;
                hdd.complete();
            } else if let Some(dl) = hdd.idle_deadline() {
                now = dl;
            } else {
                break;
            }
        }
        assert!(
            hdd.total_busy_us > ds.done_at as f64 * 5.0,
            "random HDD ({}) should dwarf SSD append ({})",
            hdd.total_busy_us,
            ds.done_at
        );
    }

    #[test]
    fn busy_until_complete() {
        let mut s = Ssd::<u8>::new(SsdConfig::default());
        s.enqueue_append(512, 1);
        let d = s.try_dispatch(0).unwrap();
        s.enqueue_append(512, 2);
        assert!(s.try_dispatch(1).is_none());
        s.complete();
        assert!(s.try_dispatch(d.done_at).is_some());
    }

    #[test]
    fn read_throughput_accounted() {
        let mut s = Ssd::<u8>::new(SsdConfig::default());
        s.enqueue_read(2048, 0);
        let d = s.try_dispatch(0).unwrap();
        s.complete();
        assert_eq!(s.bytes_read, 2048 * 512);
        assert!(d.done_at > 0);
    }
}
