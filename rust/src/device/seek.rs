//! Piecewise-linear HDD seek-time model.
//!
//! The paper (citing FS2 [12]) notes disk seek time is linearly related to
//! logical-address distance in most cases; we use a two-segment linear
//! model with a full-stroke clamp. **The constants must match
//! python/compile/constants.py** — the AOT seek-cost kernel bakes them in,
//! and rust/src/runtime/artifacts.rs cross-checks the manifest at load
//! time so the two implementations cannot drift silently.

/// Two-segment linear seek model (microseconds vs sector distance).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SeekModel {
    pub knee_sectors: i64,
    pub short_base_us: f64,
    pub short_us_per_sector: f64,
    pub long_base_us: f64,
    pub long_us_per_sector: f64,
    pub cap_sectors: i64,
}

impl Default for SeekModel {
    fn default() -> Self {
        // Mirrors python/compile/constants.py.
        Self {
            knee_sectors: 2048,
            short_base_us: 500.0,
            short_us_per_sector: 0.15,
            long_base_us: 1500.0,
            long_us_per_sector: 0.0025,
            cap_sectors: 600_000,
        }
    }
}

impl SeekModel {
    /// Cost of moving the head a logical distance of `dist` sectors.
    /// `dist == 0` means the next request is adjacent: no movement.
    #[inline]
    pub fn seek_us(&self, dist: i64) -> f64 {
        let d = dist.abs();
        if d == 0 {
            0.0
        } else if d <= self.knee_sectors {
            self.short_base_us + self.short_us_per_sector * d as f64
        } else {
            self.long_base_us + self.long_us_per_sector * d.min(self.cap_sectors) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_distance_is_free() {
        assert_eq!(SeekModel::default().seek_us(0), 0.0);
    }

    #[test]
    fn short_and_long_branches() {
        let m = SeekModel::default();
        let short = m.seek_us(m.knee_sectors);
        assert!((short - (500.0 + 0.15 * 2048.0)).abs() < 1e-9);
        let long = m.seek_us(m.knee_sectors + 1);
        assert!((long - (1500.0 + 0.0025 * 2049.0)).abs() < 1e-9);
        // the model is intentionally discontinuous at the knee (real seek
        // curves jump when the arm transitions from settle-dominated to
        // coast-dominated); just check both branches are positive+ordered
        assert!(long > short);
    }

    #[test]
    fn symmetric_in_direction() {
        let m = SeekModel::default();
        assert_eq!(m.seek_us(12345), m.seek_us(-12345));
    }

    #[test]
    fn capped_at_full_stroke() {
        let m = SeekModel::default();
        assert_eq!(m.seek_us(m.cap_sectors), m.seek_us(m.cap_sectors * 10));
    }

    #[test]
    fn monotone_within_branches() {
        let m = SeekModel::default();
        let mut prev = 0.0;
        for d in [1, 10, 100, 1000, 2048] {
            let c = m.seek_us(d);
            assert!(c > prev);
            prev = c;
        }
        let mut prev = 0.0;
        for d in [2049, 10_000, 100_000, 600_000] {
            let c = m.seek_us(d);
            assert!(c > prev);
            prev = c;
        }
    }
}
