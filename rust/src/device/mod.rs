//! Simulated storage devices (DESIGN.md §Substitutions).
//!
//! The paper's evaluation runs on a real SATA HDD + Intel DC S3520 SSD;
//! these models reproduce the *cost structure* every SSDUP+ mechanism
//! exploits: seeks proportional to sorted-offset gaps (HDD), an elevator
//! queue that merges adjacent requests (CFQ), near-zero seek plus
//! append-friendly writes (SSD).

pub mod hdd;
pub mod seek;
pub mod ssd;

pub use hdd::{Hdd, HddConfig};
pub use seek::SeekModel;
pub use ssd::{Ssd, SsdConfig};
