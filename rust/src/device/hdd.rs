//! HDD model with a CFQ-like scheduler.
//!
//! CFQ (*Completely Fair Queuing*, the paper's testbed default) keeps
//! per-process queues and services one process at a time with
//! anticipation: it keeps serving a process while that process keeps its
//! queue non-empty and its time slice (quantum) has not expired. The
//! scheduler can only reorder what fits in its bounded backlog
//! (`nr_requests`, default 128; Fig 12 sweeps 32/512) — excess submissions
//! block (modeled as an overflow FIFO admitted as the queue drains).
//!
//! These three mechanisms — per-writer slicing with anticipation, a seek
//! cost per head movement, and the bounded backlog — jointly reproduce the
//! paper's §2.2 observations: per-process sequential streams are fast; a
//! process count approaching the queue depth degrades every pattern
//! (slices shrink toward one request); a larger queue restores merging.
//! The flusher enqueues under its own writer id ([`FLUSH_WRITER`]), so a
//! flush competes with direct writes exactly like another application —
//! the I/O interference of §2.4.2.

use crate::device::seek::SeekModel;
use crate::types::{sectors_to_bytes, Usec};

/// Writer id used by the flusher (modeled as one more process).
pub const FLUSH_WRITER: u32 = u32::MAX;

#[derive(Clone, Copy, Debug)]
pub struct HddConfig {
    /// sequential transfer bandwidth, MB/s (== bytes/us)
    pub seq_mbps: f64,
    /// per-request submission/completion overhead, us
    pub per_io_us: f64,
    /// CFQ backlog bound (nr_requests): max requests the scheduler holds
    pub queue_size: usize,
    /// CFQ time slice: how long one writer may monopolize the head
    pub quantum_us: f64,
    /// anticipatory idle: how long to wait for the slice holder's next
    /// request before rotating (CFQ slice_idle). Disabled while the
    /// backlog is congested (overflow non-empty), like CFQ under load.
    pub idle_us: f64,
    pub seek: SeekModel,
}

impl Default for HddConfig {
    fn default() -> Self {
        Self {
            // Calibrated so one I/O node peaks near the paper's §2.2
            // observations (218 MB/s aggregate over 2 nodes for contiguous,
            // ~95 MB/s aggregate floor for random).
            seq_mbps: 130.0,
            per_io_us: 20.0,
            queue_size: 128,
            quantum_us: 25_000.0,
            idle_us: 8_000.0,
            seek: SeekModel::default(),
        }
    }
}

/// One queued I/O plus its completion tag.
#[derive(Clone, Copy, Debug)]
struct QueuedIo<T> {
    lba: i64,
    sectors: i64,
    writer: u32,
    tag: T,
}

/// Result of dispatching one CFQ window.
#[derive(Clone, Debug)]
pub struct Dispatch<T> {
    /// completion time for the whole window
    pub done_at: Usec,
    /// tags of every request served in this window
    pub tags: Vec<T>,
    /// number of head movements the sorted window needed
    pub seeks: u64,
    /// service time breakdown, us
    pub seek_us: f64,
    pub transfer_us: f64,
}

/// Simulated HDD.
pub struct Hdd<T> {
    pub cfg: HddConfig,
    head: i64,
    busy: bool,
    /// admitted backlog (bounded by queue_size)
    queue: std::collections::VecDeque<QueuedIo<T>>,
    /// submissions beyond the backlog bound (blocked submitters)
    overflow: std::collections::VecDeque<QueuedIo<T>>,
    /// round-robin rotation over writers (CFQ fairness)
    rr: std::collections::VecDeque<u32>,
    /// writer currently holding the slice + service consumed in it
    current_writer: Option<u32>,
    slice_service_us: f64,
    /// anticipatory idle deadline: while set and in the future, dispatch
    /// holds off serving other writers, waiting for the slice holder
    idle_deadline: Option<Usec>,
    /// writers whose last window was seek-dominated: CFQ does not idle
    /// for seeky processes (there is no locality to protect)
    seeky: std::collections::HashSet<u32>,
    /// per-writer admitted-request counts (§Perf: replaces O(queue) scans
    /// in the dispatcher hot path)
    pending: std::collections::HashMap<u32, u32>,
    pub total_idle_us: f64,
    // lifetime stats
    pub bytes_written: u64,
    pub total_seeks: u64,
    pub total_busy_us: f64,
    pub total_seek_us: f64,
    pub dispatches: u64,
    pub merged_runs: u64,
}

impl<T: Copy> Hdd<T> {
    pub fn new(cfg: HddConfig) -> Self {
        Self {
            cfg,
            head: 0,
            busy: false,
            queue: std::collections::VecDeque::new(),
            overflow: std::collections::VecDeque::new(),
            rr: std::collections::VecDeque::new(),
            current_writer: None,
            slice_service_us: 0.0,
            idle_deadline: None,
            seeky: std::collections::HashSet::new(),
            pending: std::collections::HashMap::new(),
            total_idle_us: 0.0,
            bytes_written: 0,
            total_seeks: 0,
            total_busy_us: 0.0,
            total_seek_us: 0.0,
            dispatches: 0,
            merged_runs: 0,
        }
    }

    pub fn is_busy(&self) -> bool {
        self.busy
    }

    pub fn queued(&self) -> usize {
        self.queue.len() + self.overflow.len()
    }

    fn admit(&mut self, io: QueuedIo<T>) {
        let n = self.pending.entry(io.writer).or_insert(0);
        if *n == 0 && !self.rr.contains(&io.writer) {
            self.rr.push_back(io.writer);
        }
        *n += 1;
        self.queue.push_back(io);
    }

    /// Enqueue a write at absolute disk address `lba` (sectors) on behalf
    /// of `writer` (a process id, or [`FLUSH_WRITER`] for the flusher).
    pub fn enqueue(&mut self, lba: i64, sectors: i64, writer: u32, tag: T) {
        debug_assert!(sectors > 0);
        let io = QueuedIo { lba, sectors, writer, tag };
        if self.queue.len() < self.cfg.queue_size {
            self.admit(io);
        } else {
            self.overflow.push_back(io);
        }
    }

    fn writer_has_pending(&self, w: u32) -> bool {
        self.pending.get(&w).copied().unwrap_or(0) > 0
    }

    /// Pick the writer to serve: continue the current slice while its
    /// owner has pending requests and quantum left; otherwise rotate.
    fn pick_writer(&mut self) -> Option<u32> {
        if let Some(w) = self.current_writer {
            if self.slice_service_us < self.cfg.quantum_us && self.writer_has_pending(w) {
                return Some(w);
            }
            // slice over: requeue the writer at the back
            self.rr.retain(|&x| x != w);
            if self.writer_has_pending(w) {
                self.rr.push_back(w);
            }
            self.current_writer = None;
            self.slice_service_us = 0.0;
        }
        loop {
            let w = *self.rr.front()?;
            if self.writer_has_pending(w) {
                self.current_writer = Some(w);
                self.slice_service_us = 0.0;
                return Some(w);
            }
            self.rr.pop_front();
        }
    }

    /// If dispatch is currently held by anticipation, the deadline the
    /// caller should poke the device at (DES wake-up contract).
    pub fn idle_deadline(&self) -> Option<Usec> {
        self.idle_deadline
    }

    /// If idle and the queue is non-empty, dispatch one window: up to
    /// `max(1, queue_size / active_writers)` requests of the slice-holding
    /// writer, sorted by LBA, merged where adjacent. Returns the
    /// completion descriptor; the caller must invoke `complete()` at
    /// `done_at` (DES contract).
    pub fn try_dispatch(&mut self, now: Usec) -> Option<Dispatch<T>> {
        if self.busy || self.queue.is_empty() {
            return None;
        }
        // anticipatory idling: the slice holder has quantum left but its
        // next request has not arrived yet — hold dispatch briefly instead
        // of paying an inter-segment seek (CFQ slice_idle). The hold is a
        // *hint*, not a busy period: the caller polls again on the next
        // arrival (serving the holder instantly) or at `idle_deadline()`.
        // Skipped while the backlog is congested, as CFQ does under load.
        if let Some(w) = self.current_writer {
            let anticipate = self.cfg.idle_us > 0.0
                && self.slice_service_us < self.cfg.quantum_us
                && !self.writer_has_pending(w)
                && self.overflow.is_empty()
                && !self.seeky.contains(&w);
            if anticipate {
                match self.idle_deadline {
                    None => {
                        self.idle_deadline = Some(now + self.cfg.idle_us.ceil() as Usec);
                        return None;
                    }
                    Some(d) if now < d => return None,
                    Some(d) => {
                        // anticipation expired: account and rotate
                        self.total_idle_us +=
                            self.cfg.idle_us - (d.saturating_sub(now)) as f64;
                        self.idle_deadline = None;
                        self.slice_service_us = f64::INFINITY; // force rotation
                    }
                }
            } else if let Some(d) = self.idle_deadline.take() {
                // the holder came back (or congestion hit) before the
                // deadline: charge only the time actually waited
                let waited = self.cfg.idle_us - (d.saturating_sub(now)) as f64;
                self.total_idle_us += waited.max(0.0);
            }
        }
        let writer = self.pick_writer()?;
        let active = self.rr.len().max(1);
        let window_cap = (self.cfg.queue_size / active).max(1);
        // the window may not overrun the writer's remaining quantum
        // (estimated by transfer time; seeks are charged after the fact)
        let quantum_left = (self.cfg.quantum_us - self.slice_service_us).max(0.0);
        let mut est_us = 0.0;
        let mut window: Vec<QueuedIo<T>> = Vec::with_capacity(window_cap.min(64));
        let mut i = 0;
        while i < self.queue.len() && window.len() < window_cap {
            if self.queue[i].writer == writer {
                let io = self.queue.remove(i).unwrap();
                *self.pending.get_mut(&writer).expect("tracked writer") -= 1;
                est_us +=
                    sectors_to_bytes(io.sectors) as f64 / self.cfg.seq_mbps + self.cfg.per_io_us;
                window.push(io);
                if est_us >= quantum_left {
                    break;
                }
            } else {
                i += 1;
            }
        }
        debug_assert!(!window.is_empty());
        // admit blocked submissions into the freed backlog space
        while self.queue.len() < self.cfg.queue_size {
            match self.overflow.pop_front() {
                Some(io) => self.admit(io),
                None => break,
            }
        }
        // elevator: sort the window by disk address
        window.sort_by_key(|io| io.lba);

        let mut seek_us = 0.0;
        let mut transfer_us = 0.0;
        let mut seeks = 0u64;
        let mut runs = 0u64;
        let mut pos = self.head;
        let mut bytes = 0u64;
        for io in &window {
            let dist = (io.lba - pos).abs();
            let cost = self.cfg.seek.seek_us(dist);
            if cost > 0.0 {
                seeks += 1;
                seek_us += cost;
            } else {
                runs += 1;
            }
            let b = sectors_to_bytes(io.sectors);
            bytes += b;
            transfer_us += b as f64 / self.cfg.seq_mbps;
            pos = io.lba + io.sectors;
        }
        let service_us = seek_us + transfer_us + self.cfg.per_io_us * window.len() as f64;
        // CFQ seekiness heuristic: a window dominated by head movements
        // marks the writer seeky (no anticipation for it next time)
        if seeks as usize * 2 > window.len() {
            self.seeky.insert(writer);
        } else {
            self.seeky.remove(&writer);
        }
        self.head = pos;
        self.busy = true;
        self.slice_service_us += service_us;
        self.bytes_written += bytes;
        self.total_seeks += seeks;
        self.total_seek_us += seek_us;
        self.total_busy_us += service_us;
        self.dispatches += 1;
        self.merged_runs += runs;
        Some(Dispatch {
            done_at: now + service_us.ceil() as Usec,
            tags: window.iter().map(|io| io.tag).collect(),
            seeks,
            seek_us,
            transfer_us,
        })
    }

    /// Mark the in-flight window complete (DES event handler calls this).
    pub fn complete(&mut self) {
        debug_assert!(self.busy, "complete() without dispatch");
        self.busy = false;
    }

    /// Mean achieved bandwidth so far, MB/s.
    pub fn achieved_mbps(&self) -> f64 {
        if self.total_busy_us == 0.0 {
            0.0
        } else {
            self.bytes_written as f64 / self.total_busy_us
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hdd() -> Hdd<u32> {
        Hdd::new(HddConfig::default())
    }

    /// Drain the device fully (honouring anticipation deadlines),
    /// returning total busy time.
    fn drain(h: &mut Hdd<u32>) -> f64 {
        let mut now = 0;
        loop {
            if let Some(d) = h.try_dispatch(now) {
                now = d.done_at;
                h.complete();
            } else if let Some(dl) = h.idle_deadline() {
                now = dl;
            } else {
                break;
            }
        }
        h.total_busy_us
    }

    #[test]
    fn idle_empty_does_not_dispatch() {
        let mut h = hdd();
        assert!(h.try_dispatch(0).is_none());
    }

    #[test]
    fn single_writer_contiguous_run_has_one_seek() {
        let mut h = hdd();
        for i in 0..10 {
            h.enqueue(1_000_000 + i * 512, 512, 7, i as u32);
        }
        let d = h.try_dispatch(0).unwrap();
        assert_eq!(d.tags.len(), 10);
        assert_eq!(d.seeks, 1, "one repositioning, then a merged run");
    }

    #[test]
    fn out_of_order_arrivals_are_merged_by_elevator() {
        let mut h = hdd();
        let mut order: Vec<i64> = (0..10).collect();
        order.reverse();
        for (i, blk) in order.iter().enumerate() {
            h.enqueue(blk * 512, 512, 1, i as u32);
        }
        let d = h.try_dispatch(0).unwrap();
        assert_eq!(d.seeks, 0, "starts at head position 0 and merges fully");
    }

    #[test]
    fn random_window_pays_per_request_seeks() {
        let mut h = hdd();
        let mut lba = 0i64;
        for i in 0..32 {
            lba += 1_000_000;
            h.enqueue(lba, 512, 1, i as u32);
        }
        drain(&mut h);
        assert_eq!(h.total_seeks, 32, "every random request seeks");
        assert!(h.total_seek_us > h.total_busy_us / 2.0, "random writes are seek-bound");
    }

    #[test]
    fn busy_device_defers_dispatch_until_complete() {
        let mut h = hdd();
        h.enqueue(0, 512, 1, 1);
        let d1 = h.try_dispatch(0).unwrap();
        h.enqueue(512, 512, 1, 2);
        assert!(h.try_dispatch(1).is_none(), "busy until complete()");
        h.complete();
        let d2 = h.try_dispatch(d1.done_at).unwrap();
        assert_eq!(d2.tags, vec![2]);
    }

    #[test]
    fn window_shrinks_with_more_writers() {
        // 128-deep queue, 4 writers -> windows of up to 32; 128 writers ->
        // windows of 1 (the Fig 2 degradation mechanism)
        let mut h = hdd();
        for w in 0..4u32 {
            for i in 0..30i64 {
                h.enqueue(w as i64 * 100_000_000 + i * 64, 64, w, w);
            }
        }
        let d = h.try_dispatch(0).unwrap();
        assert_eq!(d.tags.len(), 30.min(128 / 4), "window = backlog share");
        h.complete();

        let mut h2 = hdd();
        for w in 0..128u32 {
            h2.enqueue(w as i64 * 1_000_000, 512, w, w);
        }
        let d2 = h2.try_dispatch(0).unwrap();
        assert_eq!(d2.tags.len(), 1, "window = 128/128");
    }

    #[test]
    fn anticipation_keeps_serving_one_writer_within_quantum() {
        let mut h = Hdd::<u32>::new(HddConfig { queue_size: 4, ..Default::default() });
        for w in 0..3u32 {
            for i in 0..3i64 {
                h.enqueue(w as i64 * 10_000_000 + i * 512, 512, w, w);
            }
        }
        let mut served = Vec::new();
        let mut now = 0;
        loop {
            if let Some(d) = h.try_dispatch(now) {
                served.extend(d.tags.clone());
                now = d.done_at;
                h.complete();
            } else if let Some(dl) = h.idle_deadline() {
                now = dl;
            } else {
                break;
            }
        }
        assert_eq!(served.len(), 9);
        // the slice holder is drained before rotating (quantum 25ms is
        // far larger than 3 tiny writes)
        assert_eq!(&served[0..3], &[0, 0, 0]);
        assert_eq!(&served[3..6], &[1, 1, 1]);
        assert_eq!(&served[6..9], &[2, 2, 2]);
    }

    #[test]
    fn quantum_bounds_a_writer_monopoly() {
        // writer 0 has a huge contiguous backlog; writer 1 one request;
        // writer 1 must be served before writer 0 finishes everything
        let mut h = Hdd::<u32>::new(HddConfig { quantum_us: 5_000.0, ..Default::default() });
        for i in 0..64i64 {
            h.enqueue(i * 512, 512, 0, 0);
        }
        h.enqueue(500_000_000, 512, 1, 1);
        let mut first_w1_at = None;
        let mut served = 0;
        let mut now = 0;
        loop {
            if let Some(d) = h.try_dispatch(now) {
                for t in &d.tags {
                    if *t == 1 && first_w1_at.is_none() {
                        first_w1_at = Some(served);
                    }
                    served += 1;
                }
                now = d.done_at;
                h.complete();
            } else if let Some(dl) = h.idle_deadline() {
                now = dl;
            } else {
                break;
            }
        }
        let at = first_w1_at.expect("writer 1 served");
        assert!(at < 40, "quantum must preempt writer 0 (w1 served after {at} requests)");
    }

    #[test]
    fn bounded_backlog_blocks_excess_submissions() {
        let mut h =
            Hdd::<u32>::new(HddConfig { queue_size: 8, quantum_us: 1e9, ..Default::default() });
        for i in 0..20i64 {
            h.enqueue(i * 512, 512, 0, i as u32);
        }
        assert_eq!(h.queued(), 20, "total tracked");
        let d = h.try_dispatch(0).unwrap();
        assert_eq!(d.tags.len(), 8, "window bounded by admitted backlog");
        h.complete();
        // freed space admitted the next 8
        let d2 = h.try_dispatch(d.done_at).unwrap();
        assert_eq!(d2.tags.len(), 8);
    }

    #[test]
    fn contiguous_faster_than_strided_faster_than_random() {
        // the §2.2 ordering, with 16 writers and interleaved arrival
        let procs = 16u32;
        let per = 32i64;
        let req = 512i64;
        let run = |pattern: &str| -> f64 {
            let mut h = hdd();
            for i in 0..per {
                for w in 0..procs {
                    let (lba, writer) = match pattern {
                        "contig" => ((w as i64 * per + i) * req, w),
                        "strided" => ((i * procs as i64 + w as i64) * req, w),
                        _ => {
                            let x = (w as i64 * 7919 + i * 104_729) % 100_000;
                            (x * req, w)
                        }
                    };
                    h.enqueue(lba, req, writer, w);
                }
            }
            drain(&mut h)
        };
        let c = run("contig");
        let s = run("strided");
        let r = run("random");
        assert!(c < s, "contiguous {c:.0}us should beat strided {s:.0}us");
        assert!(s < r, "strided {s:.0}us should beat random {r:.0}us");
    }

    #[test]
    fn larger_queue_helps_many_writers() {
        // Fig 12 mechanism: 32 writers, interleaved arrival; queue 32
        // admits ~1 per writer (no merging), queue 512 admits everything
        let run = |qsize: usize| -> f64 {
            let mut h = Hdd::<u32>::new(HddConfig { queue_size: qsize, ..Default::default() });
            for i in 0..16i64 {
                for w in 0..32u32 {
                    h.enqueue(w as i64 * 100_000_000 + i * 512, 512, w, w);
                }
            }
            drain(&mut h)
        };
        let small = run(32);
        let large = run(512);
        assert!(
            large < small * 0.75,
            "queue=512 ({large:.0}us) should be far cheaper than queue=32 ({small:.0}us)"
        );
    }

    #[test]
    fn achieved_mbps_reasonable_for_sequential() {
        let mut h = hdd();
        for i in 0..128i64 {
            h.enqueue(i * 512, 512, 0, 0);
        }
        drain(&mut h);
        let bw = h.achieved_mbps();
        assert!(bw > 100.0 && bw <= 130.0, "sequential bw {bw} MB/s");
    }
}
