//! Workload substrate: generators reproducing the access patterns of the
//! paper's three benchmarks (IOR §4.2, HPIO §4.3, MPI-Tile-IO §4.4).
//!
//! A workload is a set of closed-loop processes, each with a request
//! sequence in issue order. The simulator interleaves them (I/O depth +
//! jitter), which is what creates the server-side randomness the paper's
//! detector measures — per-process sequences here are exactly the
//! patterns the benchmarks describe.

pub mod hpio;
pub mod ior;
pub mod mpitileio;
pub mod rewrite;

use std::collections::HashMap;

use crate::types::Request;

/// One application process: a request sequence issued in order.
#[derive(Clone, Debug)]
pub struct ProcessWorkload {
    pub app: u16,
    pub proc_id: u32,
    pub reqs: Vec<Request>,
    /// the process starts only after this app has fully completed, plus a
    /// compute gap (Fig 14's computing-time sweep); None = start at t=0
    pub after_app: Option<(u16, u64)>,
}

/// A full workload: one or more applications' processes.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub processes: Vec<ProcessWorkload>,
}

impl Workload {
    pub fn total_bytes(&self) -> u64 {
        self.processes.iter().flat_map(|p| &p.reqs).map(|r| r.bytes()).sum()
    }

    pub fn total_requests(&self) -> usize {
        self.processes.iter().map(|p| p.reqs.len()).sum()
    }

    pub fn apps(&self) -> Vec<u16> {
        let mut v: Vec<u16> = self.processes.iter().map(|p| p.app).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Rank of every app in the `after_app` dependency chain: apps with
    /// no dependency are rank 0; an app gated on a rank-k app is k+1.
    /// Writes by a higher-rank app always happen after a lower-rank one,
    /// which is what makes rewrites across apps verifiable (the final
    /// copy of a sector is the highest-ranked writer's).
    pub fn app_ranks(&self) -> HashMap<u16, u32> {
        let mut dep: HashMap<u16, u16> = HashMap::new();
        for p in &self.processes {
            if let Some((d, _)) = p.after_app {
                if d != p.app {
                    dep.insert(p.app, d);
                }
            }
        }
        let mut ranks = HashMap::new();
        for p in &self.processes {
            let mut rank = 0u32;
            let mut cur = p.app;
            while let Some(&d) = dep.get(&cur) {
                rank += 1;
                cur = d;
                if rank as usize > dep.len() {
                    break; // defensive: a dependency cycle cannot rank
                }
            }
            ranks.insert(p.app, rank);
        }
        ranks
    }

    /// Merge two workloads into a concurrent mixed load, remapping the
    /// second one's app/file/proc ids to stay disjoint.
    pub fn concurrent(name: &str, a: Workload, b: Workload) -> Workload {
        let max_app = a.processes.iter().map(|p| p.app).max().unwrap_or(0);
        let max_file =
            a.processes.iter().flat_map(|p| &p.reqs).map(|r| r.file).max().unwrap_or(0);
        let max_proc = a.processes.iter().map(|p| p.proc_id).max().unwrap_or(0);
        let mut processes = a.processes;
        for mut p in b.processes {
            p.app += max_app + 1;
            p.proc_id += max_proc + 1;
            if let Some((dep, gap)) = p.after_app {
                p.after_app = Some((dep + max_app + 1, gap));
            }
            for r in &mut p.reqs {
                r.app += max_app + 1;
                r.proc_id += max_proc + 1;
                r.file += max_file + 1;
            }
            processes.push(p);
        }
        Workload { name: name.to_string(), processes }
    }

    /// Run workload `b` after `a` completes, with a compute gap (Fig 14).
    pub fn sequential(name: &str, a: Workload, gap_us: u64, b: Workload) -> Workload {
        let a_app = a.processes.first().map(|p| p.app).unwrap_or(0);
        let mut merged = Self::concurrent(name, a, b);
        let apps = merged.apps();
        let b_apps: Vec<u16> = apps.into_iter().filter(|&x| x != a_app).collect();
        for p in &mut merged.processes {
            if b_apps.contains(&p.app) {
                p.after_app = Some((a_app, gap_us));
            }
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::DEFAULT_REQ_SECTORS;

    fn tiny(app: u16) -> Workload {
        ior::segmented_contiguous(app, 4, 64, DEFAULT_REQ_SECTORS)
    }

    #[test]
    fn concurrent_keeps_ids_disjoint() {
        let w = Workload::concurrent("mix", tiny(0), tiny(0));
        let apps = w.apps();
        assert_eq!(apps.len(), 2);
        let files: std::collections::HashSet<u32> =
            w.processes.iter().flat_map(|p| &p.reqs).map(|r| r.file).collect();
        assert_eq!(files.len(), 2, "each app writes its own shared file");
        let procs: std::collections::HashSet<u32> =
            w.processes.iter().map(|p| p.proc_id).collect();
        assert_eq!(procs.len(), 8);
    }

    #[test]
    fn sequential_sets_dependency() {
        let w = Workload::sequential("seq", tiny(0), 5_000_000, tiny(0));
        let deps: Vec<_> = w.processes.iter().filter_map(|p| p.after_app).collect();
        assert_eq!(deps.len(), 4, "all of app B's processes wait");
        assert!(deps.iter().all(|&(app, gap)| app == 0 && gap == 5_000_000));
    }

    #[test]
    fn app_ranks_follow_dependency_chain() {
        let w = Workload::sequential("seq", tiny(0), 1000, tiny(0));
        let ranks = w.app_ranks();
        assert_eq!(ranks[&0], 0);
        assert_eq!(ranks[&1], 1);
        // `sequential` gates every later app on the *first* app of `a`,
        // so a third app also lands at rank 1
        let w3 = Workload::sequential("seq3", w, 1000, tiny(0));
        let r3 = w3.app_ranks();
        assert_eq!((r3[&0], r3[&1], r3[&2]), (0, 1, 1));
    }

    #[test]
    fn totals_add_up() {
        let w = Workload::concurrent("mix", tiny(0), tiny(1));
        assert_eq!(w.total_requests(), 2 * 4 * 64);
        assert_eq!(w.total_bytes(), 2 * 4 * 64 * 256 * 1024);
    }
}
