//! HPIO workload generator (paper §4.3): region-based non-contiguous I/O.
//!
//! Parameters mirror the benchmark: region size, region count, region
//! spacing, and the non-contiguous test array. The paper runs two
//! instances: `c-c` (file-contiguous) and `c-nc` (file non-contiguous).

use crate::types::Request;
use crate::workload::{ProcessWorkload, Workload};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HpioMode {
    /// contiguous in memory and file (test array 1000)
    ContiguousContiguous,
    /// contiguous memory, non-contiguous file (test array 0010): process
    /// regions interleave with `spacing` sectors between a process's
    /// consecutive regions
    ContiguousNonContiguous,
}

/// Build one HPIO instance.
///
/// * `region_sectors` — region size (the paper sweeps 32 KB..256 KB);
/// * `region_count` — regions per process (chosen to hold file size);
/// * `spacing_sectors` — distance between adjacent regions (paper: 0; in
///   c-nc mode the *other processes'* regions provide the distance).
pub fn hpio(
    app: u16,
    mode: HpioMode,
    procs: u32,
    region_sectors: i32,
    region_count: usize,
    spacing_sectors: i32,
) -> Workload {
    let file = app as u32;
    let processes = (0..procs)
        .map(|p| {
            let reqs = (0..region_count)
                .map(|i| {
                    let offset = match mode {
                        HpioMode::ContiguousContiguous => {
                            // process p owns a contiguous run of regions
                            (p as i32 * region_count as i32 + i as i32)
                                * (region_sectors + spacing_sectors)
                        }
                        HpioMode::ContiguousNonContiguous => {
                            // regions deal round-robin across processes:
                            // region i of process p sits at (i*procs + p)
                            (i as i32 * procs as i32 + p as i32)
                                * (region_sectors + spacing_sectors)
                        }
                    };
                    Request { app, proc_id: p, file, offset, size: region_sectors }
                })
                .collect();
            ProcessWorkload { app, proc_id: p, reqs, after_app: None }
        })
        .collect();
    let m = match mode {
        HpioMode::ContiguousContiguous => "c-c",
        HpioMode::ContiguousNonContiguous => "c-nc",
    };
    Workload { name: format!("hpio-{m}-p{procs}-rs{region_sectors}"), processes }
}

/// The paper's §4.3 configuration: two concurrent HPIO instances (c-c ×
/// c-nc), 32 processes total, file ~8 GB each; region count derived from
/// region size to keep the file size fixed.
pub fn paper_mixed(region_sectors: i32, procs_per_instance: u32, file_sectors: i64) -> Workload {
    let per_proc = (file_sectors / (region_sectors as i64 * procs_per_instance as i64)).max(1) as usize;
    let a = hpio(0, HpioMode::ContiguousContiguous, procs_per_instance, region_sectors, per_proc, 0);
    let b = hpio(0, HpioMode::ContiguousNonContiguous, procs_per_instance, region_sectors, per_proc, 0);
    Workload::concurrent(&format!("hpio-mixed-rs{region_sectors}"), a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cc_is_contiguous_per_process_and_globally() {
        let w = hpio(0, HpioMode::ContiguousContiguous, 4, 64, 8, 0);
        for p in &w.processes {
            assert!(p.reqs.windows(2).all(|r| r[1].offset == r[0].end()));
        }
    }

    #[test]
    fn cnc_interleaves_processes() {
        let w = hpio(0, HpioMode::ContiguousNonContiguous, 4, 64, 8, 0);
        // process 0's consecutive regions are procs*region apart
        for p in &w.processes {
            assert!(p.reqs.windows(2).all(|r| r[1].offset - r[0].offset == 4 * 64));
        }
        // globally the regions tile the file exactly
        let mut offs: Vec<i32> = w.processes.iter().flat_map(|p| &p.reqs).map(|r| r.offset).collect();
        offs.sort_unstable();
        assert!(offs.windows(2).all(|r| r[1] == r[0] + 64));
    }

    #[test]
    fn spacing_creates_holes() {
        let w = hpio(0, HpioMode::ContiguousContiguous, 1, 64, 4, 16);
        let p = &w.processes[0];
        assert!(p.reqs.windows(2).all(|r| r[1].offset - r[0].offset == 80));
    }

    #[test]
    fn paper_mixed_has_two_apps_same_size() {
        let w = paper_mixed(512, 16, 1 << 21);
        assert_eq!(w.apps().len(), 2);
        let by_app: Vec<u64> = w
            .apps()
            .iter()
            .map(|&a| {
                w.processes
                    .iter()
                    .filter(|p| p.app == a)
                    .flat_map(|p| &p.reqs)
                    .map(|r| r.bytes())
                    .sum()
            })
            .collect();
        assert_eq!(by_app[0], by_app[1]);
    }

    #[test]
    fn region_count_scales_inversely_with_region_size() {
        let small = paper_mixed(64, 16, 1 << 21);
        let large = paper_mixed(512, 16, 1 << 21);
        assert_eq!(small.total_bytes(), large.total_bytes());
        assert!(small.total_requests() > large.total_requests());
    }
}
