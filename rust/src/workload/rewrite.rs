//! Rewrite-heavy workloads: every sector written at least twice, with the
//! two passes shaped to take *different* routes through the burst buffer.
//!
//! The checkpoint-rewrite pattern is the overwrite-safety stress the
//! live engine's ownership map exists for: a checkpoint app scatters the
//! file in random request order (after the first detection window these
//! land in the SSD log), then a rewrite app — gated on the checkpoint by
//! `after_app` — rewrites the same sectors sequentially. Low-randomness
//! traffic is exactly what the redirector sends straight to HDD, so the
//! second pass hits the dangerous cross-route direction: direct writes
//! over sectors whose stale copies still sit in the log.
//!
//! Per-process segments are disjoint and the passes are ordered by the
//! dependency, so the final version of every sector is well defined (the
//! rewrite pass wins). Drive it with versioned payloads
//! (`live::run_load_with(.., versioned = true)`) and check with
//! `LiveEngine::verify_workload_versioned`.

use crate::types::Request;
use crate::util::prng::Prng;
use crate::workload::{ProcessWorkload, Workload};

/// Two-phase checkpoint-rewrite workload over one shared file (see the
/// module docs). `total_sectors` is the file span per phase; every slot
/// of it is written once by each phase, so each sector is written exactly
/// twice. `gap_us` is the compute gap between the phases (Fig 14's knob).
pub fn checkpoint_rewrite(
    procs: u32,
    total_sectors: i64,
    req_sectors: i32,
    gap_us: u64,
    seed: u64,
) -> Workload {
    assert!(procs >= 1, "need at least one process per phase");
    assert!(req_sectors > 0);
    let file = 0u32;
    let mut rng = Prng::new(seed ^ 0x5EED_00F2);
    let slots = (total_sectors / req_sectors as i64).max(1);
    // balanced partition: proc p owns slots [p*slots/procs, (p+1)*slots/
    // procs), so the whole span is covered exactly once per phase even
    // when procs does not divide slots (procs > slots leaves the excess
    // processes empty, which the load generator treats as complete)
    let segment = |p: u32| -> (i64, i64) {
        (p as i64 * slots / procs as i64, (p as i64 + 1) * slots / procs as i64)
    };
    let mut processes = Vec::with_capacity(2 * procs as usize);
    // phase 1 — "checkpoint": random visit order within each segment.
    // The slot space is dense, but a detection window samples only a few
    // of a segment's slots at a time, so sorted neighbors are rarely
    // adjacent: high random percentage -> SSD log.
    for p in 0..procs {
        let (lo, hi) = segment(p);
        let mut order: Vec<i64> = (lo..hi).collect();
        rng.shuffle(&mut order);
        let reqs = order
            .into_iter()
            .map(|s| Request {
                app: 0,
                proc_id: p,
                file,
                offset: (s * req_sectors as i64) as i32,
                size: req_sectors,
            })
            .collect();
        processes.push(ProcessWorkload { app: 0, proc_id: p, reqs, after_app: None });
    }
    // phase 2 — "rewrite": the same segments in ascending order (pct ~ 0
    // -> direct-to-HDD route), gated on phase 1 completing
    for p in 0..procs {
        let (lo, hi) = segment(p);
        let reqs = (lo..hi)
            .map(|s| Request {
                app: 1,
                proc_id: procs + p,
                file,
                offset: (s * req_sectors as i64) as i32,
                size: req_sectors,
            })
            .collect();
        processes.push(ProcessWorkload {
            app: 1,
            proc_id: procs + p,
            reqs,
            after_app: Some((0, gap_us)),
        });
    }
    Workload { name: format!("checkpoint-rewrite-p{procs}x2"), processes }
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    use super::*;

    #[test]
    fn every_sector_is_written_exactly_twice() {
        let w = checkpoint_rewrite(4, 8192, 64, 1000, 7);
        let mut hits: HashMap<i32, u32> = HashMap::new();
        for proc in &w.processes {
            for req in &proc.reqs {
                for s in 0..req.size {
                    *hits.entry(req.offset + s).or_insert(0) += 1;
                }
            }
        }
        assert_eq!(hits.len(), 8192, "full span covered");
        assert!(hits.values().all(|&c| c == 2), "each sector written twice");
    }

    #[test]
    fn uneven_proc_counts_still_cover_the_whole_span() {
        // 1024/64 = 16 slots over 3 procs: 5+5+6, no gap, no overflow
        let w = checkpoint_rewrite(3, 1024, 64, 0, 5);
        let mut hits: HashMap<i32, u32> = HashMap::new();
        for proc in &w.processes {
            for req in &proc.reqs {
                assert!(req.offset >= 0 && req.offset + req.size <= 1024, "{req:?}");
                for s in 0..req.size {
                    *hits.entry(req.offset + s).or_insert(0) += 1;
                }
            }
        }
        assert_eq!(hits.len(), 1024, "no tail slot dropped");
        assert!(hits.values().all(|&c| c == 2));
        // more procs than slots: excess processes are simply empty
        let w2 = checkpoint_rewrite(8, 256, 64, 0, 5);
        let total: i32 = w2.processes.iter().flat_map(|p| &p.reqs).map(|r| r.size).sum();
        assert_eq!(total, 2 * 256, "each phase writes the span exactly once");
        assert!(w2.processes.iter().flat_map(|p| &p.reqs).all(|r| r.end() <= 256));
    }

    #[test]
    fn rewrite_phase_is_gated_and_ordered() {
        let w = checkpoint_rewrite(4, 8192, 64, 5000, 7);
        assert_eq!(w.processes.len(), 8);
        let ranks = w.app_ranks();
        assert_eq!((ranks[&0], ranks[&1]), (0, 1));
        for proc in w.processes.iter().filter(|p| p.app == 1) {
            assert_eq!(proc.after_app, Some((0, 5000)));
            // ascending rewrite order (the HDD-routed shape)
            assert!(proc.reqs.windows(2).all(|w| w[1].offset > w[0].offset));
        }
        // the checkpoint phase visits its slots in shuffled order
        let any_shuffled = w.processes.iter().filter(|p| p.app == 0).any(|p| {
            let offs: Vec<i32> = p.reqs.iter().map(|r| r.offset).collect();
            let mut sorted = offs.clone();
            sorted.sort_unstable();
            offs != sorted
        });
        assert!(any_shuffled, "checkpoint phase must visit randomly");
    }

    #[test]
    fn proc_ids_are_disjoint_across_phases() {
        let w = checkpoint_rewrite(3, 4096, 64, 0, 9);
        let ids: std::collections::HashSet<u32> =
            w.processes.iter().map(|p| p.proc_id).collect();
        assert_eq!(ids.len(), 6);
    }
}
