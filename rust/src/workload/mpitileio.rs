//! MPI-Tile-IO workload generator (paper §4.4): tiled access to a dense
//! 2-D dataset. Each process owns one tile; writing a tile touches one
//! row-segment per dataset row it spans, so 2-D tilings produce strided
//! file patterns while 1-D (x=1) tilings degenerate to segmented runs.

use crate::types::Request;
use crate::workload::{ProcessWorkload, Workload};

/// Build one MPI-Tile-IO instance over an `x_tiles` x `y_tiles` grid
/// (procs = x*y). Each tile is `tile_w` x `tile_h` elements of
/// `elem_sectors` sectors each.
pub fn mpi_tile_io(
    app: u16,
    x_tiles: u32,
    y_tiles: u32,
    tile_w: u32,
    tile_h: u32,
    elem_sectors: i32,
) -> Workload {
    let file = app as u32;
    let row_elems = x_tiles * tile_w; // dataset row width in elements
    let mut processes = Vec::with_capacity((x_tiles * y_tiles) as usize);
    for ty in 0..y_tiles {
        for tx in 0..x_tiles {
            let proc_id = ty * x_tiles + tx;
            let mut reqs = Vec::with_capacity(tile_h as usize);
            for r in 0..tile_h {
                let row = ty * tile_h + r;
                let elem_off = row * row_elems + tx * tile_w;
                reqs.push(Request {
                    app,
                    proc_id,
                    file,
                    offset: elem_off as i32 * elem_sectors,
                    size: tile_w as i32 * elem_sectors,
                });
            }
            processes.push(ProcessWorkload { app, proc_id, reqs, after_app: None });
        }
    }
    Workload { name: format!("mpi-tile-io-{x_tiles}x{y_tiles}"), processes }
}

/// The paper's §4.4 pair: instance 1 is 1-D (x=1, y=procs), instance 2 is
/// 2-D (x = floor(sqrt(procs)), y = procs/x); element size 4 KB
/// (8 sectors); tile dimensions sized so each instance writes
/// `total_sectors`.
pub fn paper_pair(procs: u32, total_sectors: i64) -> Workload {
    let elem_sectors = 8; // 4 KB
    // instance 1: 1-D — one tile per process, tile_w elements wide rows
    let elems_total = (total_sectors / elem_sectors as i64) as u64;
    let elems_per_proc = elems_total / procs as u64;
    // make tiles roughly square in elements
    let tile_h1 = (elems_per_proc as f64).sqrt().round().max(1.0) as u32;
    let tile_w1 = (elems_per_proc / tile_h1 as u64).max(1) as u32;
    let a = mpi_tile_io(0, 1, procs, tile_w1, tile_h1, elem_sectors);

    let x2 = (procs as f64).sqrt().floor().max(1.0) as u32;
    let y2 = (procs / x2).max(1);
    let elems_per_proc2 = elems_total / (x2 * y2) as u64;
    let tile_h2 = (elems_per_proc2 as f64).sqrt().round().max(1.0) as u32;
    let tile_w2 = (elems_per_proc2 / tile_h2 as u64).max(1) as u32;
    let b = mpi_tile_io(0, x2, y2, tile_w2, tile_h2, elem_sectors);

    Workload::concurrent(&format!("mpi-tile-io-pair-p{procs}"), a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_d_tiling_is_segmented_contiguous() {
        // x=1: each process's rows are file-contiguous
        let w = mpi_tile_io(0, 1, 4, 16, 8, 8);
        for p in &w.processes {
            assert!(p.reqs.windows(2).all(|r| r[1].offset == r[0].end()), "{:?}", p.reqs);
        }
    }

    #[test]
    fn two_d_tiling_is_strided() {
        let w = mpi_tile_io(0, 4, 2, 8, 4, 8);
        assert_eq!(w.processes.len(), 8);
        for p in &w.processes {
            // consecutive rows of a tile stride by the full dataset row
            let stride = 4 * 8 * 8; // x_tiles * tile_w * elem_sectors
            assert!(p.reqs.windows(2).all(|r| r[1].offset - r[0].offset == stride));
        }
    }

    #[test]
    fn tiles_are_disjoint_and_cover() {
        let w = mpi_tile_io(0, 2, 2, 4, 4, 8);
        let mut offs: Vec<(i32, i32)> =
            w.processes.iter().flat_map(|p| &p.reqs).map(|r| (r.offset, r.size)).collect();
        offs.sort_unstable();
        for win in offs.windows(2) {
            assert_eq!(win[0].0 + win[0].1, win[1].0, "no gaps, no overlap");
        }
    }

    #[test]
    fn paper_pair_has_two_instances() {
        let w = paper_pair(16, 1 << 20);
        assert_eq!(w.apps().len(), 2);
        assert_eq!(
            w.processes.iter().filter(|p| p.app == w.apps()[0]).count(),
            16
        );
        // sizes approximately equal (rounding from tile fitting)
        let sizes: Vec<u64> = w
            .apps()
            .iter()
            .map(|&a| {
                w.processes.iter().filter(|p| p.app == a).flat_map(|p| &p.reqs).map(|r| r.bytes()).sum()
            })
            .collect();
        let ratio = sizes[0] as f64 / sizes[1] as f64;
        assert!((0.8..1.25).contains(&ratio), "sizes {sizes:?}");
    }
}
