//! IOR workload generator (paper §2.2/§4.2): segmented-contiguous,
//! segmented-random, and strided write patterns against one shared file.

use crate::types::Request;
use crate::util::prng::Prng;
use crate::workload::{ProcessWorkload, Workload};

/// Segmented-contiguous: each of `procs` processes owns a 1/n slice of the
/// shared file and writes it sequentially.
pub fn segmented_contiguous(app: u16, procs: u32, reqs_per_proc: usize, req_sectors: i32) -> Workload {
    let file = app as u32;
    let processes = (0..procs)
        .map(|p| {
            let base = p as i32 * reqs_per_proc as i32 * req_sectors;
            let reqs = (0..reqs_per_proc)
                .map(|i| Request {
                    app,
                    proc_id: p,
                    file,
                    offset: base + i as i32 * req_sectors,
                    size: req_sectors,
                })
                .collect();
            ProcessWorkload { app, proc_id: p, reqs, after_app: None }
        })
        .collect();
    Workload { name: format!("ior-segmented-contiguous-p{procs}"), processes }
}

/// Segmented-random: like segmented-contiguous but each process visits
/// random request slots of its segment. `span_sectors` sets the *offset
/// space* (segment width = span/procs): when a workload is scaled down
/// for simulation speed, pass the unscaled file size here so the offsets
/// stay as sparse as the paper's — a shrunken random file sorts back to
/// near-contiguous and stops being random at all (scale artifact).
pub fn segmented_random_spanned(
    app: u16,
    procs: u32,
    reqs_per_proc: usize,
    req_sectors: i32,
    span_sectors: i64,
    seed: u64,
) -> Workload {
    let file = app as u32;
    let mut rng = Prng::new(seed ^ 0x5EED_0001);
    let seg_slots = (span_sectors / (req_sectors as i64 * procs as i64)).max(1) as u64;
    let processes = (0..procs)
        .map(|p| {
            let base = p as i64 * seg_slots as i64 * req_sectors as i64;
            let mut prng = rng.fork(p as u64);
            let k = (reqs_per_proc as u64).min(seg_slots) as usize;
            let mut slots = prng.sample_distinct(seg_slots, k);
            // Floyd sampling emits a near-ascending order; the *visit*
            // order must be random too
            prng.shuffle(&mut slots);
            let reqs = slots
                .into_iter()
                .map(|s| Request {
                    app,
                    proc_id: p,
                    file,
                    offset: (base + s as i64 * req_sectors as i64) as i32,
                    size: req_sectors,
                })
                .collect();
            ProcessWorkload { app, proc_id: p, reqs, after_app: None }
        })
        .collect();
    Workload { name: format!("ior-segmented-random-p{procs}"), processes }
}

/// Segmented-random over a dense slot space (span = procs * reqs * size).
pub fn segmented_random(
    app: u16,
    procs: u32,
    reqs_per_proc: usize,
    req_sectors: i32,
    seed: u64,
) -> Workload {
    let span = procs as i64 * reqs_per_proc as i64 * req_sectors as i64;
    segmented_random_spanned(app, procs, reqs_per_proc, req_sectors, span, seed)
}

/// Strided: in iteration i, process j writes offset (i * procs + j) * req.
pub fn strided(app: u16, procs: u32, iterations: usize, req_sectors: i32) -> Workload {
    let file = app as u32;
    let processes = (0..procs)
        .map(|j| {
            let reqs = (0..iterations)
                .map(|i| Request {
                    app,
                    proc_id: j,
                    file,
                    offset: (i as i32 * procs as i32 + j as i32) * req_sectors,
                    size: req_sectors,
                })
                .collect();
            ProcessWorkload { app, proc_id: j, reqs, after_app: None }
        })
        .collect();
    Workload { name: format!("ior-strided-p{procs}"), processes }
}

/// Convenience: build an IOR instance by total size (the paper quotes
/// 16 GB / 8 GB files with 256 KB requests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IorPattern {
    SegmentedContiguous,
    SegmentedRandom,
    Strided,
}

pub fn ior(
    app: u16,
    pattern: IorPattern,
    procs: u32,
    total_sectors: i64,
    req_sectors: i32,
    seed: u64,
) -> Workload {
    ior_spanned(app, pattern, procs, total_sectors, total_sectors, req_sectors, seed)
}

/// Like [`ior`] but with an explicit offset span for the random pattern
/// (pass the *unscaled* file size when simulating a scaled-down volume).
pub fn ior_spanned(
    app: u16,
    pattern: IorPattern,
    procs: u32,
    total_sectors: i64,
    span_sectors: i64,
    req_sectors: i32,
    seed: u64,
) -> Workload {
    let total_reqs = (total_sectors / req_sectors as i64) as usize;
    let per_proc = (total_reqs / procs as usize).max(1);
    match pattern {
        IorPattern::SegmentedContiguous => segmented_contiguous(app, procs, per_proc, req_sectors),
        IorPattern::SegmentedRandom => {
            segmented_random_spanned(app, procs, per_proc, req_sectors, span_sectors, seed)
        }
        IorPattern::Strided => strided(app, procs, per_proc, req_sectors),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detector::native::detect_stream;

    #[test]
    fn contiguous_per_process_is_sequential() {
        let w = segmented_contiguous(0, 4, 16, 512);
        for p in &w.processes {
            assert!(p.reqs.windows(2).all(|w| w[1].offset == w[0].end()));
        }
        // slices are disjoint and tile the file
        let mut offs: Vec<i32> = w.processes.iter().flat_map(|p| &p.reqs).map(|r| r.offset).collect();
        offs.sort_unstable();
        assert!(offs.windows(2).all(|w| w[1] == w[0] + 512));
    }

    #[test]
    fn random_is_permutation_of_contiguous() {
        let c = segmented_contiguous(0, 4, 16, 512);
        let r = segmented_random(0, 4, 16, 512, 7);
        let norm = |w: &Workload| {
            let mut v: Vec<i32> = w.processes.iter().flat_map(|p| &p.reqs).map(|x| x.offset).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(norm(&c), norm(&r));
        // but at least one process is actually shuffled
        assert!(r.processes.iter().any(|p| p.reqs.windows(2).any(|w| w[1].offset != w[0].end())));
    }

    #[test]
    fn random_detected_as_fully_random_within_a_process() {
        let r = segmented_random(0, 1, 128, 512, 3);
        let stream: Vec<(i32, i32)> = r.processes[0].reqs.iter().map(|q| (q.offset, q.size)).collect();
        // a single process's shuffled slice still *sorts* back to fully
        // contiguous -> S = 0; randomness appears only in bounded windows
        let d = detect_stream(&stream);
        assert_eq!(d.s, 0, "full-permutation sorts back to contiguous");
        // a bounded window sees only part of the permutation: roughly half
        // the sorted neighbours are missing -> substantial randomness
        let d64 = detect_stream(&stream[..64]);
        assert!(d64.percentage > 0.3, "a 64-window of the permutation is random: {}", d64.percentage);
    }

    #[test]
    fn strided_covers_file_densely() {
        let w = strided(0, 8, 16, 512);
        let mut offs: Vec<i32> = w.processes.iter().flat_map(|p| &p.reqs).map(|r| r.offset).collect();
        offs.sort_unstable();
        assert_eq!(offs.len(), 128);
        assert!(offs.windows(2).all(|w| w[1] == w[0] + 512));
        // per process, offsets stride by procs*req
        for p in &w.processes {
            assert!(p.reqs.windows(2).all(|w| w[1].offset - w[0].offset == 8 * 512));
        }
    }

    #[test]
    fn ior_by_total_size() {
        // 1 GiB = 2097152 sectors, 256 KB reqs = 512 sectors -> 4096 reqs
        let w = ior(0, IorPattern::Strided, 16, 2_097_152, 512, 0);
        assert_eq!(w.total_requests(), 4096);
        assert_eq!(w.total_bytes(), 1 << 30);
    }

    #[test]
    fn deterministic_generation() {
        let a = segmented_random(0, 4, 32, 512, 42);
        let b = segmented_random(0, 4, 32, 512, 42);
        for (pa, pb) in a.processes.iter().zip(&b.processes) {
            assert_eq!(pa.reqs, pb.reqs);
        }
    }
}
