//! Flush-interference scenario (paper §2.4.2 / Fig 9): two applications —
//! one sequential, one random — share the I/O nodes while the SSD is too
//! small to hold the random working set. Shows why *when* you flush
//! matters: SSDUP flushes the moment a region fills and collides with the
//! sequential app's direct HDD writes; SSDUP+'s traffic-aware strategy
//! pauses until the direct traffic ebbs.
//!
//! Run: `cargo run --release --example mixed_interference`

use ssdup::server::{simulate, SimConfig, SystemKind};
use ssdup::types::DEFAULT_REQ_SECTORS;
use ssdup::workload::ior::{ior_spanned, IorPattern};
use ssdup::workload::Workload;

fn main() {
    let gb = 2 * 1024 * 1024; // 1 GiB in sectors
    let w = Workload::concurrent(
        "checkpointer x analyzer",
        ior_spanned(0, IorPattern::SegmentedContiguous, 16, gb, gb * 8, DEFAULT_REQ_SECTORS, 3),
        ior_spanned(0, IorPattern::SegmentedRandom, 16, gb, gb * 8, DEFAULT_REQ_SECTORS, 4),
    );
    println!("workload: {} ({} MiB total)\n", w.name, w.total_bytes() >> 20);

    println!(
        "{:<12} {:>14} {:>14} {:>9} {:>10} {:>9}",
        "system", "seq app MB/s", "rand app MB/s", "flushes", "pause s", "blocked"
    );
    for system in [SystemKind::Ssdup, SystemKind::SsdupPlus] {
        // SSD sized to half the data so flushing overlaps the writes
        let cfg = SimConfig::new(system).with_seed(3).with_ssd_mib(512);
        let r = simulate(&cfg, &w);
        println!(
            "{:<12} {:>14.1} {:>14.1} {:>9} {:>10.1} {:>9}",
            r.system,
            r.per_app[0].throughput_mbps(),
            r.per_app[1].throughput_mbps(),
            r.nodes.iter().map(|n| n.flushes).sum::<u64>(),
            r.total_flush_pause_us() as f64 / 1e6,
            r.nodes.iter().map(|n| n.blocked_requests).sum::<u64>(),
        );
    }
    println!("\nSSDUP+ should hold both apps above SSDUP by deferring flushes (paper: +34.85%).");
}
