//! Live-engine quickstart: run a mixed contiguous×random workload through
//! the real-time sharded burst buffer (in-memory backends with synthetic
//! device latency), then verify every byte on the HDD backends.
//!
//! Run: `cargo run --release --example live_quickstart`

use ssdup::live::{self, LiveConfig, LiveEngine, SyntheticLatency};
use ssdup::server::SystemKind;
use ssdup::types::DEFAULT_REQ_SECTORS;
use ssdup::workload::ior::{ior_spanned, IorPattern};
use ssdup::workload::Workload;

fn main() {
    // 128 MiB mixed load: one contiguous app, one random app
    let sectors = 128 * 2048 / 2;
    let span = sectors * 16;
    let workload = Workload::concurrent(
        "live-quickstart-mixed",
        ior_spanned(0, IorPattern::SegmentedContiguous, 8, sectors, span, DEFAULT_REQ_SECTORS, 7),
        ior_spanned(0, IorPattern::SegmentedRandom, 8, sectors, span, DEFAULT_REQ_SECTORS, 8),
    );

    println!("live SSDUP+ engine: 4 shards, in-memory backends, 8 closed-loop clients\n");
    let cfg = LiveConfig::new(SystemKind::SsdupPlus).with_shards(4).with_ssd_mib(32);
    let engine = LiveEngine::mem(&cfg, SyntheticLatency::ssd(), SyntheticLatency::hdd());

    let report = live::run_load(&engine, &workload, 8);
    println!("{}\n", report.summary());
    for (i, s) in report.shards.iter().enumerate() {
        println!(
            "  shard {i}: {} streams, rp {:>5.1}% | ssd {:>3} MiB, direct {:>3} MiB, \
             {} flushes ({} paused)",
            s.streams,
            s.mean_percentage() * 100.0,
            s.ssd_bytes_buffered / (1 << 20),
            s.hdd_direct_bytes / (1 << 20),
            s.flushes,
            s.flush_pauses,
        );
    }

    let verify = engine.verify_workload(&workload);
    println!(
        "\nverify: {} ({} MiB checked, {} mismatched sectors)",
        if verify.is_ok() { "OK" } else { "FAILED" },
        verify.checked_bytes / (1 << 20),
        verify.mismatched_sectors
    );
    engine.shutdown();
    assert!(verify.is_ok());
}
