//! End-to-end driver: the full three-layer stack on a realistic workload.
//!
//! This is the repo's proof that all layers compose:
//!   L1/L2 — the JAX/Pallas traffic-detection kernels, AOT-lowered to HLO
//!            text by `make artifacts`;
//!   runtime — the Rust PJRT client compiles and executes them;
//!   L3  — the SSDUP+ I/O-node servers run the paper's §4.2.3 mixed
//!         workload with detection *on the compiled path* (one node uses
//!         the HLO backend, one the native mirror — their decisions must
//!         coincide), and we report the paper's headline metrics:
//!         throughput vs the baselines and SSD bytes saved.
//!
//! Run: `make artifacts && cargo run --release --example e2e_paper`

use ssdup::detector::hlo::{DetectBackend, HloDetector};
use ssdup::detector::native::NativeDetector;
use ssdup::runtime::Runtime;
use ssdup::server::{simulate, simulate_with_backends, SimConfig, SystemKind};
use ssdup::types::DEFAULT_REQ_SECTORS;
use ssdup::workload::ior::{ior_spanned, IorPattern};
use ssdup::workload::Workload;

fn mixed_workload() -> Workload {
    let gb = 2 * 1024 * 1024; // 1 GiB in sectors
    Workload::concurrent(
        "e2e: ior-contiguous x ior-random",
        ior_spanned(0, IorPattern::SegmentedContiguous, 16, gb, gb * 8, DEFAULT_REQ_SECTORS, 7),
        ior_spanned(0, IorPattern::SegmentedRandom, 16, gb, gb * 8, DEFAULT_REQ_SECTORS, 8),
    )
}

fn main() -> anyhow::Result<()> {
    println!("=== SSDUP+ end-to-end driver ===\n");

    // --- load the AOT artifacts and compile on PJRT -----------------------
    let rt = Runtime::load_default().map_err(|e| {
        anyhow::anyhow!("{e}\nhint: run `make artifacts` first")
    })?;
    println!("[1/4] PJRT platform: {}; artifacts: {}", rt.platform(), rt.artifacts.dir.display());
    let det_exec = rt.detector()?;
    println!(
        "      compiled detector.hlo.txt (batch={}, nmax={})",
        det_exec.batch, det_exec.nmax
    );

    // --- sanity: compiled kernels agree with the native mirror ------------
    let mut hlo = HloDetector::new(det_exec);
    let mut native = NativeDetector::default();
    let probe: Vec<(i32, i32)> = (0..128).map(|i| ((i * 37 % 128) * 512, 512)).collect();
    let d_hlo = hlo.detect(&probe);
    let d_nat = DetectBackend::detect(&mut native, &probe);
    assert_eq!(d_hlo.s, d_nat.s, "HLO and native detectors must agree");
    println!("[2/4] HLO vs native cross-check: S={} percentage={:.3} OK", d_hlo.s, d_hlo.percentage);

    // --- run the paper's mixed workload with HLO detection on node 0 ------
    let w = mixed_workload();
    let cfg = SimConfig::new(SystemKind::SsdupPlus).with_seed(7).with_ssd_mib(1024);
    let backends: Vec<Box<dyn DetectBackend>> =
        vec![Box::new(hlo), Box::new(NativeDetector::default())];
    let t0 = std::time::Instant::now();
    let plus = simulate_with_backends(&cfg, &w, backends);
    let wall = t0.elapsed();
    println!(
        "[3/4] SSDUP+ (node0=HLO, node1=native): {:.1} MB/s, ssd {:.1}%, {} streams detected, wall {:.2}s",
        plus.throughput_mbps(),
        plus.ssd_ratio * 100.0,
        plus.nodes.iter().map(|n| n.streams).sum::<u64>(),
        wall.as_secs_f64()
    );

    // --- headline comparison ----------------------------------------------
    println!("[4/4] baselines (same workload, same SSD budget):");
    println!(
        "      {:<12} {:>10} {:>10} {:>12} {:>10}",
        "system", "MB/s", "ssd %", "ssd bytes", "pauses s"
    );
    let mut bb_bytes = 0u64;
    for system in [SystemKind::OrangeFs, SystemKind::OrangeFsBB, SystemKind::Ssdup] {
        let r = simulate(&SimConfig::new(system).with_seed(7).with_ssd_mib(1024), &w);
        if system == SystemKind::OrangeFsBB {
            bb_bytes = r.ssd_bytes();
        }
        println!(
            "      {:<12} {:>10.1} {:>9.1}% {:>12} {:>10.1}",
            r.system,
            r.throughput_mbps(),
            r.ssd_ratio * 100.0,
            r.ssd_bytes(),
            r.total_flush_pause_us() as f64 / 1e6
        );
    }
    println!(
        "      {:<12} {:>10.1} {:>9.1}% {:>12} {:>10.1}",
        plus.system,
        plus.throughput_mbps(),
        plus.ssd_ratio * 100.0,
        plus.ssd_bytes(),
        plus.total_flush_pause_us() as f64 / 1e6
    );
    if bb_bytes > 0 {
        let saved = 1.0 - plus.ssd_bytes() as f64 / bb_bytes as f64;
        println!(
            "\nheadline: SSDUP+ saved {:.1}% of the SSD bytes OrangeFS-BB used (paper: ~50% average)",
            saved * 100.0
        );
    }
    Ok(())
}
