//! Quickstart: simulate one strided IOR workload under all four systems
//! and print the throughput / SSD-usage comparison — the paper's core
//! claim in ~30 lines.
//!
//! Run: `cargo run --release --example quickstart`

use ssdup::server::{simulate, SimConfig, SystemKind};
use ssdup::types::DEFAULT_REQ_SECTORS;
use ssdup::workload::ior::{ior_spanned, IorPattern};

fn main() {
    // 2 GiB strided IOR over 32 processes (offset span kept at 16 GiB so
    // the pattern's randomness matches the paper's full-size run)
    let data_sectors = 4 * 1024 * 1024;
    let workload = ior_spanned(
        0,
        IorPattern::Strided,
        32,
        data_sectors,
        data_sectors * 8,
        DEFAULT_REQ_SECTORS,
        42,
    );

    println!(
        "workload: {} ({} MiB, {} requests)\n",
        workload.name,
        workload.total_bytes() >> 20,
        workload.total_requests()
    );
    println!("{:<12} {:>12} {:>10} {:>10} {:>9}", "system", "MB/s", "ssd %", "random %", "flushes");
    for system in SystemKind::ALL {
        let cfg = SimConfig::new(system).with_seed(42);
        let r = simulate(&cfg, &workload);
        println!(
            "{:<12} {:>12.1} {:>9.1}% {:>9.1}% {:>9}",
            r.system,
            r.throughput_mbps(),
            r.ssd_ratio * 100.0,
            r.mean_percentage * 100.0,
            r.nodes.iter().map(|n| n.flushes).sum::<u64>(),
        );
    }
    println!("\nSSDUP+ should match OrangeFS-BB's throughput while buffering far less data.");
}
