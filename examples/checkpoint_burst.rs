//! Checkpoint-burst scenario (paper §1 motivation + Fig 14): an HPC
//! application alternates computation phases with bursty checkpoint dumps.
//! A classic burst buffer needs the computation phase to be long enough to
//! hide its blocking flush; SSDUP+'s two-region pipeline keeps absorbing
//! new bursts while the previous one drains.
//!
//! Run: `cargo run --release --example checkpoint_burst`

use ssdup::server::{simulate, SimConfig, SystemKind};
use ssdup::types::DEFAULT_REQ_SECTORS;
use ssdup::workload::ior::{ior_spanned, IorPattern};
use ssdup::workload::Workload;

fn main() {
    let burst = 1024 * 1024; // 512 MiB checkpoint in sectors
    println!("two 512 MiB checkpoint bursts, SSD = 50% of the data\n");
    println!(
        "{:<8} {:>16} {:>14} {:>8}",
        "gap s", "orangefs-bb MB/s", "ssdup+ MB/s", "gain"
    );
    for gap_s in [0u64, 1, 2, 4, 8] {
        // each burst is a 16-process random-ish dump (checkpoint shards
        // land interleaved at the server)
        let a = ior_spanned(0, IorPattern::SegmentedRandom, 16, burst, burst * 8, DEFAULT_REQ_SECTORS, 1);
        let b = ior_spanned(0, IorPattern::SegmentedRandom, 16, burst, burst * 8, DEFAULT_REQ_SECTORS, 2);
        let w = Workload::sequential("checkpoint-bursts", a, gap_s * 1_000_000, b);
        let mut results = Vec::new();
        for system in [SystemKind::OrangeFsBB, SystemKind::SsdupPlus] {
            let cfg = SimConfig::new(system).with_seed(1).with_ssd_mib(256);
            let r = simulate(&cfg, &w);
            // app-visible bandwidth, averaged over the two bursts
            let t = (r.per_app[0].throughput_mbps() + r.per_app[1].throughput_mbps()) / 2.0;
            results.push(t);
        }
        println!(
            "{:<8} {:>16.1} {:>14.1} {:>7.1}%",
            gap_s,
            results[0],
            results[1],
            (results[1] / results[0] - 1.0) * 100.0
        );
    }
    println!("\nSSDUP+'s advantage is largest at short gaps (pipeline vs blocking flush).");
}
