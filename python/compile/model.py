"""L2 JAX model: the SSDUP+ traffic-detection compute graph.

Two exported computations, each AOT-lowered by `aot.py` to one fused HLO
module the Rust coordinator executes via PJRT:

* `detect(offsets, sizes, lengths)` — the per-stream analytics of paper
  §2.2/§2.3.1: mask padding, argsort offsets (stable), co-permute sizes,
  then the Pallas kernels compute the random-factor sum S (Eq. 1) and the
  HDD seek-cost estimate; percentage = S / (length-1).
* `threshold(percent_list, count)` — the adaptive threshold of Eq. 2/3
  over a sorted PercentList.

Shapes are static (BATCH x NMAX with per-stream `length` masking) so a
single artifact serves every stream length the paper uses (32/128/512,
Fig. 12). Everything here is build-time only; Rust never imports Python.
"""

import jax
import jax.numpy as jnp

from compile import constants as C
from compile.kernels.random_factor import random_factor
from compile.kernels.seek_cost import seek_cost


def detect(offsets, sizes, lengths):
    """Batch traffic detection. Returns (S, percentage, seek_cost_us).

    offsets, sizes: int32 [BATCH, NMAX] in 512-byte sectors.
    lengths: int32 [BATCH]; entries at i >= length are ignored.
    """
    n = offsets.shape[1]
    idx = jnp.arange(n, dtype=jnp.int32)[None, :]
    valid = idx < lengths[:, None]
    off_masked = jnp.where(valid, offsets, jnp.int32(C.OFFSET_PAD))
    size_masked = jnp.where(valid, sizes, jnp.int32(0))
    order = jnp.argsort(off_masked, axis=1, stable=True)
    sorted_off = jnp.take_along_axis(off_masked, order, axis=1)
    sorted_size = jnp.take_along_axis(size_masked, order, axis=1)

    s = random_factor(sorted_off, sorted_size, lengths)
    denom = jnp.maximum(lengths - 1, 1).astype(jnp.float32)
    percentage = jnp.where(lengths > 1, s.astype(jnp.float32) / denom, 0.0)
    cost = seek_cost(sorted_off, sorted_size, lengths)
    return s, percentage.astype(jnp.float32), cost


def threshold(percent_list, count):
    """Adaptive threshold selection (paper Eq. 2/3).

    percent_list: float32 [PERCENT_LIST_CAP], ascending over [:count].
    count: int32 scalar. Returns (threshold, avgper) float32 scalars.
    """
    k = percent_list.shape[0]
    idx = jnp.arange(k, dtype=jnp.int32)
    valid = idx < count
    cnt = jnp.maximum(count, 1).astype(jnp.float32)
    avgper = jnp.sum(jnp.where(valid, percent_list, 0.0)) / cnt
    sel = jnp.floor((1.0 - avgper) * (count - 1).astype(jnp.float32))
    sel = jnp.clip(sel.astype(jnp.int32), 0, jnp.maximum(count - 1, 0))
    return percent_list[sel].astype(jnp.float32), avgper.astype(jnp.float32)


def detect_abstract_args():
    """ShapeDtypeStructs matching what the Rust runtime feeds `detect`."""
    return (
        jax.ShapeDtypeStruct((C.BATCH, C.NMAX), jnp.int32),
        jax.ShapeDtypeStruct((C.BATCH, C.NMAX), jnp.int32),
        jax.ShapeDtypeStruct((C.BATCH,), jnp.int32),
    )


def threshold_abstract_args():
    return (
        jax.ShapeDtypeStruct((C.PERCENT_LIST_CAP,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
