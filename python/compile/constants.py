"""Shared compile-time constants for the SSDUP+ analytics artifacts.

These values are baked into the AOT-lowered HLO and mirrored on the Rust
side (rust/src/runtime/artifacts.rs reads them back from the manifest that
aot.py emits next to the artifacts). Offsets and sizes are expressed in
512-byte sectors as int32: a 16 GiB file spans 33,554,432 sectors, well
within i32 range, which keeps the kernels free of x64 headaches.
"""

# Batch of request streams processed per PJRT execute call (L3 pads).
BATCH: int = 16

# Maximum stream length. The paper's default stream is 128 requests (the
# CFQ queue depth); the Fig-12 experiment also uses 32 and 512, so the
# artifact is lowered at the maximum and shorter streams are masked via the
# per-stream `length` input.
NMAX: int = 512

# Padding value for offsets beyond `length`: sorts to the end.
OFFSET_PAD: int = 2**31 - 1

# Seek-cost model (must match rust/src/device/hdd.rs). Piecewise-linear
# seek time in microseconds as a function of logical gap in sectors:
#   gap == 0          -> 0 (merged request, no head movement)
#   0 < gap <= KNEE   -> SHORT_BASE_US + SHORT_US_PER_SECTOR * gap
#   gap  > KNEE       -> LONG_BASE_US + LONG_US_PER_SECTOR * min(gap, CAP)
# backwards gaps cost the same as forwards (|gap|).
SEEK_KNEE_SECTORS: int = 2048  # 1 MiB
SEEK_SHORT_BASE_US: float = 500.0
SEEK_SHORT_US_PER_SECTOR: float = 0.15
SEEK_LONG_BASE_US: float = 1500.0
SEEK_LONG_US_PER_SECTOR: float = 0.0025
SEEK_CAP_SECTORS: int = 600_000  # full-stroke clamp (~300 MiB logical)

# PercentList capacity for the adaptive-threshold artifact (L3 masks by
# `count`). The paper's case study uses a 10-entry history.
PERCENT_LIST_CAP: int = 64
