"""L1 Pallas kernel: HDD seek-cost estimate of a sorted request stream.

Same tiling story as `random_factor.py`; the body evaluates the
piecewise-linear seek model from `compile.constants` (mirrored by
rust/src/device/hdd.rs) over adjacent sorted pairs and row-reduces. The
traffic-aware flusher (rust/src/buffer/pipeline.rs) uses this estimate to
decide whether HDD is currently too busy to absorb a flush.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import constants as C

BLOCK_B = 16


def _seek_kernel(off_ref, size_ref, len_ref, cost_ref):
    off = off_ref[...]  # [Bt, N] int32 sorted
    size = size_ref[...]  # [Bt, N] int32
    lengths = len_ref[...]  # [Bt]
    gaps = off[:, 1:] - off[:, :-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, gaps.shape, 1)
    valid = idx < (lengths[:, None] - 1)
    seq = gaps == size[:, :-1]
    dist = jnp.abs(gaps - size[:, :-1]).astype(jnp.float32)
    short = C.SEEK_SHORT_BASE_US + C.SEEK_SHORT_US_PER_SECTOR * dist
    capped = jnp.minimum(dist, jnp.float32(C.SEEK_CAP_SECTORS))
    long = C.SEEK_LONG_BASE_US + C.SEEK_LONG_US_PER_SECTOR * capped
    cost = jnp.where(dist <= C.SEEK_KNEE_SECTORS, short, long)
    cost = jnp.where(valid & ~seq, cost, 0.0)
    cost_ref[...] = jnp.sum(cost, axis=1, dtype=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def seek_cost(sorted_off, sorted_size, lengths):
    """Estimated microseconds of head movement per stream. float32 [B]."""
    b, n = sorted_off.shape
    assert b % BLOCK_B == 0, f"batch {b} not a multiple of {BLOCK_B}"
    grid = (b // BLOCK_B,)
    return pl.pallas_call(
        _seek_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, n), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, n), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(sorted_off, sorted_size, lengths)
