"""Pure-jnp oracle for the SSDUP+ analytics kernels.

This module is the CORE correctness signal: the Pallas kernels in
`random_factor.py` / `seek_cost.py` and the full L2 model in `model.py`
must match these reference implementations bit-for-bit (int outputs) or to
float tolerance (seek cost), across every shape/pattern pytest sweeps.

Everything operates on int32 offsets/sizes in 512-byte sectors; see
`compile.constants` for the unit rationale.
"""

import jax.numpy as jnp

from compile import constants as C


def sort_stream(offsets, sizes, lengths):
    """Sort each stream by offset, masking padded tail entries.

    offsets, sizes: int32 [B, N]; lengths: int32 [B].
    Returns (sorted_off, sorted_size) where entries at i >= length are
    OFFSET_PAD / 0 and sorted to the end. This mirrors the sorting step of
    the paper's §2.2 (Fig. 4): the detector orders the 128-request stream
    before counting head movements.
    """
    n = offsets.shape[1]
    idx = jnp.arange(n, dtype=jnp.int32)[None, :]
    valid = idx < lengths[:, None]
    off_masked = jnp.where(valid, offsets, jnp.int32(C.OFFSET_PAD))
    size_masked = jnp.where(valid, sizes, jnp.int32(0))
    order = jnp.argsort(off_masked, axis=1, stable=True)
    sorted_off = jnp.take_along_axis(off_masked, order, axis=1)
    sorted_size = jnp.take_along_axis(size_masked, order, axis=1)
    return sorted_off, sorted_size


def random_factor_ref(sorted_off, sorted_size, lengths):
    """Reference for the random-factor kernel (paper Eq. 1).

    RF_i = 0 when the i+1-th sorted request starts exactly where the i-th
    ends (offset gap == request size), else 1; S = sum over the first
    length-1 adjacent pairs. Returns int32 [B].
    """
    gaps = sorted_off[:, 1:] - sorted_off[:, :-1]
    n1 = gaps.shape[1]
    idx = jnp.arange(n1, dtype=jnp.int32)[None, :]
    valid = idx < (lengths[:, None] - 1)
    rf = jnp.where(valid & (gaps != sorted_size[:, :-1]), 1, 0)
    return jnp.sum(rf, axis=1).astype(jnp.int32)


def seek_cost_ref(sorted_off, sorted_size, lengths):
    """Reference for the seek-cost kernel: estimated microseconds of HDD
    head movement to serve the sorted stream (piecewise-linear model from
    `compile.constants`, mirrored by rust/src/device/hdd.rs).

    A pair with gap == size is a merged sequential continuation: zero seek.
    Returns float32 [B].
    """
    gaps = sorted_off[:, 1:] - sorted_off[:, :-1]
    n1 = gaps.shape[1]
    idx = jnp.arange(n1, dtype=jnp.int32)[None, :]
    valid = idx < (lengths[:, None] - 1)
    seq = gaps == sorted_size[:, :-1]
    dist = jnp.abs(gaps - sorted_size[:, :-1]).astype(jnp.float32)
    short = C.SEEK_SHORT_BASE_US + C.SEEK_SHORT_US_PER_SECTOR * dist
    capped = jnp.minimum(dist, jnp.float32(C.SEEK_CAP_SECTORS))
    long = C.SEEK_LONG_BASE_US + C.SEEK_LONG_US_PER_SECTOR * capped
    cost = jnp.where(dist <= C.SEEK_KNEE_SECTORS, short, long)
    cost = jnp.where(valid & ~seq, cost, 0.0)
    return jnp.sum(cost, axis=1).astype(jnp.float32)


def detect_ref(offsets, sizes, lengths):
    """Full reference detector: sort + RF + percentage + seek cost.

    percentage = S / (length - 1)   (paper §2.3.1), 0 for length <= 1.
    """
    sorted_off, sorted_size = sort_stream(offsets, sizes, lengths)
    s = random_factor_ref(sorted_off, sorted_size, lengths)
    denom = jnp.maximum(lengths - 1, 1).astype(jnp.float32)
    percentage = jnp.where(lengths > 1, s.astype(jnp.float32) / denom, 0.0)
    cost = seek_cost_ref(sorted_off, sorted_size, lengths)
    return s, percentage.astype(jnp.float32), cost


def threshold_ref(percent_list, count):
    """Reference adaptive threshold (paper Eq. 2/3).

    percent_list: float32 [K], sorted ascending over the first `count`
    entries (padding beyond `count` is ignored). Returns (threshold,
    avgper) as float32 scalars:
        avgper    = mean(percent_list[:count])
        threshold = percent_list[floor((1 - avgper) * (count - 1))]
    """
    k = percent_list.shape[0]
    idx = jnp.arange(k, dtype=jnp.int32)
    valid = idx < count
    cnt = jnp.maximum(count, 1).astype(jnp.float32)
    avgper = jnp.sum(jnp.where(valid, percent_list, 0.0)) / cnt
    sel = jnp.floor((1.0 - avgper) * (count - 1).astype(jnp.float32))
    sel = jnp.clip(sel.astype(jnp.int32), 0, jnp.maximum(count - 1, 0))
    threshold = percent_list[sel]
    return threshold.astype(jnp.float32), avgper.astype(jnp.float32)
