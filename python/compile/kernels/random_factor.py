"""L1 Pallas kernel: random-factor (paper Eq. 1) over sorted request streams.

The kernel consumes *sorted* per-stream offsets and the co-permuted request
sizes (sorting lives at L2 where XLA's argsort is already optimal) and
counts disk-head movements: adjacent pair i contributes RF_i = 0 iff the
next request starts exactly where the previous one ends.

TPU mapping (DESIGN.md §Hardware-Adaptation): streams are tiled
[BLOCK_B, N] into VMEM via BlockSpec; the body is elementwise compare +
row reduction on the VPU — single pass, no MXU. `interpret=True` is
mandatory on this CPU-only image (real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: BATCH=16 streams fit one block; kept as a named constant so
# the grid generalizes if BATCH grows past VMEM (see DESIGN.md §Perf).
BLOCK_B = 16


def _rf_kernel(off_ref, size_ref, len_ref, s_ref):
    """Per-block body: gaps -> compare -> masked row-sum."""
    off = off_ref[...]  # [Bt, N] int32, sorted ascending (pads at end)
    size = size_ref[...]  # [Bt, N] int32, co-permuted with off
    lengths = len_ref[...]  # [Bt] int32 valid lengths
    gaps = off[:, 1:] - off[:, :-1]  # [Bt, N-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, gaps.shape, 1)
    valid = idx < (lengths[:, None] - 1)
    rf = jnp.where(valid & (gaps != size[:, :-1]), jnp.int32(1), jnp.int32(0))
    s_ref[...] = jnp.sum(rf, axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def random_factor(sorted_off, sorted_size, lengths):
    """S[b] = sum_i RF_i for each stream b (paper Eq. 1).

    sorted_off, sorted_size: int32 [B, N] (B divisible by BLOCK_B);
    lengths: int32 [B]. Returns int32 [B].
    """
    b, n = sorted_off.shape
    assert b % BLOCK_B == 0, f"batch {b} not a multiple of {BLOCK_B}"
    grid = (b // BLOCK_B,)
    return pl.pallas_call(
        _rf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, n), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, n), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(sorted_off, sorted_size, lengths)
