"""AOT bridge: lower the L2 model to HLO *text* artifacts for the Rust side.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. Lowered with `return_tuple=True`,
unwrapped on the Rust side with `to_tuple*`.

Outputs (all under artifacts/):
  detector.hlo.txt   (S, percentage, seek_cost) = detect(off, size, len)
  threshold.hlo.txt  (threshold, avgper) = threshold(percent_list, count)
  manifest.json      shapes + shared constants, validated by
                     rust/src/runtime/artifacts.rs at load time

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import constants as C
from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_detector() -> str:
    lowered = jax.jit(model.detect).lower(*model.detect_abstract_args())
    return to_hlo_text(lowered)


def lower_threshold() -> str:
    lowered = jax.jit(model.threshold).lower(*model.threshold_abstract_args())
    return to_hlo_text(lowered)


def manifest() -> dict:
    return {
        "version": 1,
        "batch": C.BATCH,
        "nmax": C.NMAX,
        "offset_pad": C.OFFSET_PAD,
        "percent_list_cap": C.PERCENT_LIST_CAP,
        "seek_model": {
            "knee_sectors": C.SEEK_KNEE_SECTORS,
            "short_base_us": C.SEEK_SHORT_BASE_US,
            "short_us_per_sector": C.SEEK_SHORT_US_PER_SECTOR,
            "long_base_us": C.SEEK_LONG_BASE_US,
            "long_us_per_sector": C.SEEK_LONG_US_PER_SECTOR,
            "cap_sectors": C.SEEK_CAP_SECTORS,
        },
        "artifacts": {
            "detector": {
                "file": "detector.hlo.txt",
                "inputs": [
                    ["offsets", "s32", [C.BATCH, C.NMAX]],
                    ["sizes", "s32", [C.BATCH, C.NMAX]],
                    ["lengths", "s32", [C.BATCH]],
                ],
                "outputs": [
                    ["s", "s32", [C.BATCH]],
                    ["percentage", "f32", [C.BATCH]],
                    ["seek_cost_us", "f32", [C.BATCH]],
                ],
            },
            "threshold": {
                "file": "threshold.hlo.txt",
                "inputs": [
                    ["percent_list", "f32", [C.PERCENT_LIST_CAP]],
                    ["count", "s32", []],
                ],
                "outputs": [
                    ["threshold", "f32", []],
                    ["avgper", "f32", []],
                ],
            },
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file alias; writes detector HLO there and siblings next to it")
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    det = lower_detector()
    thr = lower_threshold()
    det_path = os.path.join(out_dir, "detector.hlo.txt")
    with open(det_path, "w") as f:
        f.write(det)
    with open(os.path.join(out_dir, "threshold.hlo.txt"), "w") as f:
        f.write(thr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest(), f, indent=2)
    if args.out:
        # Makefile stamp target compatibility.
        with open(args.out, "w") as f:
            f.write(det)
    print(
        f"wrote detector ({len(det)} chars), threshold ({len(thr)} chars), "
        f"manifest to {out_dir}"
    )


if __name__ == "__main__":
    main()
