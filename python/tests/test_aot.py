"""AOT path: lowering produces parseable HLO text with the right interface,
and the manifest matches the constants the Rust runtime validates."""

import json

import pytest

from compile import aot, constants as C


@pytest.fixture(scope="module")
def detector_hlo():
    return aot.lower_detector()


@pytest.fixture(scope="module")
def threshold_hlo():
    return aot.lower_threshold()


def test_detector_hlo_is_text(detector_hlo):
    assert detector_hlo.startswith("HloModule")
    # tuple-return with three leaves: s32[16], f32[16], f32[16]
    assert f"s32[{C.BATCH}]" in detector_hlo
    assert f"f32[{C.BATCH}]" in detector_hlo
    # input shapes present
    assert f"s32[{C.BATCH},{C.NMAX}]" in detector_hlo


def test_detector_hlo_contains_sort_and_reduce(detector_hlo):
    """The fused module must contain the argsort and the row reductions —
    i.e. L2 didn't silently constant-fold or drop the kernels."""
    assert "sort" in detector_hlo
    assert "reduce" in detector_hlo


def test_threshold_hlo_is_text(threshold_hlo):
    assert threshold_hlo.startswith("HloModule")
    assert f"f32[{C.PERCENT_LIST_CAP}]" in threshold_hlo


def test_no_custom_calls(detector_hlo, threshold_hlo):
    """interpret=True Pallas must lower to plain HLO — a custom-call would
    be a Mosaic op the Rust CPU PJRT client cannot execute."""
    assert "custom-call" not in detector_hlo
    assert "custom-call" not in threshold_hlo


def test_manifest_round_trip():
    m = aot.manifest()
    s = json.dumps(m)
    back = json.loads(s)
    assert back["batch"] == C.BATCH
    assert back["nmax"] == C.NMAX
    assert back["artifacts"]["detector"]["file"] == "detector.hlo.txt"
    seek = back["seek_model"]
    assert seek["knee_sectors"] == C.SEEK_KNEE_SECTORS
    assert seek["cap_sectors"] == C.SEEK_CAP_SECTORS


def test_hlo_deterministic(detector_hlo):
    """Same lowering twice -> identical text (artifact caching soundness)."""
    assert aot.lower_detector() == detector_hlo
