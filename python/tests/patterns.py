"""Access-pattern generators for kernel tests.

Miniature versions of the paper's §2.2 workload patterns, emitting
(offsets, sizes) in 512-byte sectors for a single request stream. The Rust
side has full generators under rust/src/workload/; these exist only to
exercise the kernels with realistic shapes.
"""

import numpy as np

REQ_SECTORS = 512  # 256 KB requests, the paper's default


def segmented_contiguous(n, procs=1, req=REQ_SECTORS, seed=0):
    """Each process writes its own contiguous segment; requests from the
    processes interleave round-robin (the arrival order the server sees)."""
    rng = np.random.default_rng(seed)
    per = n // procs
    offs = []
    segment = per * req * 4  # segments spaced apart
    cursors = [p * segment for p in range(procs)]
    for i in range(n):
        p = i % procs
        offs.append(cursors[p])
        cursors[p] += req
    offs = np.asarray(offs, dtype=np.int64)
    jitter = rng.integers(0, 1, size=n)  # placeholder for determinism
    return (offs + jitter).astype(np.int32), np.full(n, req, np.int32)


def segmented_random(n, file_sectors=2**25, req=REQ_SECTORS, seed=0):
    rng = np.random.default_rng(seed)
    slots = file_sectors // req
    offs = rng.choice(slots, size=n, replace=False) * req
    return offs.astype(np.int32), np.full(n, req, np.int32)


def strided(n, procs=16, req=REQ_SECTORS, seed=0):
    """Iteration i, process j accesses offset (i * procs + j) * req; arrival
    order is per-iteration with a random permutation of processes."""
    rng = np.random.default_rng(seed)
    offs = []
    i = 0
    while len(offs) < n:
        order = rng.permutation(procs)
        for j in order:
            offs.append((i * procs + int(j)) * req)
            if len(offs) == n:
                break
        i += 1
    return np.asarray(offs, dtype=np.int32), np.full(n, req, np.int32)


def mixed(n, seed=0):
    """Half segmented-contiguous, half segmented-random, interleaved —
    the two-application mixed load of Fig. 3d/5d."""
    rng = np.random.default_rng(seed)
    a_off, a_sz = segmented_contiguous(n // 2, procs=4, seed=seed)
    b_off, b_sz = segmented_random(n - n // 2, seed=seed + 1)
    offs = np.empty(n, np.int32)
    szs = np.empty(n, np.int32)
    ia = ib = 0
    for k in range(n):
        take_a = (rng.random() < 0.5 and ia < len(a_off)) or ib >= len(b_off)
        if take_a:
            offs[k], szs[k] = a_off[ia], a_sz[ia]
            ia += 1
        else:
            # shift the random app's offsets into a disjoint file region
            offs[k], szs[k] = b_off[ib] // 2 + 2**27, b_sz[ib]
            ib += 1
    return offs, szs


def pad_batch(streams, nmax, batch):
    """Pack a list of (offsets, sizes) streams into padded [batch, nmax]
    arrays + lengths, mirroring rust/src/detector/hlo.rs marshalling."""
    offsets = np.zeros((batch, nmax), np.int32)
    sizes = np.zeros((batch, nmax), np.int32)
    lengths = np.zeros((batch,), np.int32)
    for i, (o, s) in enumerate(streams):
        ln = len(o)
        offsets[i, :ln] = o
        sizes[i, :ln] = s
        lengths[i] = ln
    return offsets, sizes, lengths
