"""L2 correctness: the full detect/threshold models vs the oracle, plus
golden cases pinned to the paper's numbers."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import constants as C
from compile import model
from compile.kernels import ref

from tests import patterns


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_detect_matches_ref(seed):
    rng = np.random.default_rng(seed)
    offsets = rng.integers(0, 2**24, size=(C.BATCH, C.NMAX)).astype(np.int32)
    sizes = rng.integers(1, 4096, size=(C.BATCH, C.NMAX)).astype(np.int32)
    lengths = rng.integers(0, C.NMAX + 1, size=(C.BATCH,)).astype(np.int32)
    s, pct, cost = model.detect(jnp.asarray(offsets), jnp.asarray(sizes), jnp.asarray(lengths))
    s_r, pct_r, cost_r = ref.detect_ref(jnp.asarray(offsets), jnp.asarray(sizes), jnp.asarray(lengths))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
    np.testing.assert_allclose(np.asarray(pct), np.asarray(pct_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cost), np.asarray(cost_r), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    count=st.integers(1, C.PERCENT_LIST_CAP),
)
def test_threshold_matches_ref(seed, count):
    rng = np.random.default_rng(seed)
    vals = np.sort(rng.random(count)).astype(np.float32)
    plist = np.zeros(C.PERCENT_LIST_CAP, np.float32)
    plist[:count] = vals
    thr, avg = model.threshold(jnp.asarray(plist), jnp.int32(count))
    thr_r, avg_r = ref.threshold_ref(jnp.asarray(plist), jnp.int32(count))
    np.testing.assert_allclose(float(thr), float(thr_r), rtol=1e-6)
    np.testing.assert_allclose(float(avg), float(avg_r), rtol=1e-6)
    # the selected threshold must be an element of the live list
    assert float(thr) in [float(v) for v in vals]


def test_threshold_monotone_in_randomness():
    """Low-randomness history -> high-index (permissive) threshold;
    high-randomness history -> low-index (aggressive) threshold (§2.3.2)."""
    low = np.sort(np.linspace(0.05, 0.2, 10)).astype(np.float32)
    high = np.sort(np.linspace(0.8, 0.95, 10)).astype(np.float32)
    pl_low = np.zeros(C.PERCENT_LIST_CAP, np.float32)
    pl_low[:10] = low
    pl_high = np.zeros(C.PERCENT_LIST_CAP, np.float32)
    pl_high[:10] = high
    thr_low, _ = model.threshold(jnp.asarray(pl_low), jnp.int32(10))
    thr_high, _ = model.threshold(jnp.asarray(pl_high), jnp.int32(10))
    # permissive = near the top of the low list; aggressive = near bottom
    assert float(thr_low) >= low[7]
    assert float(thr_high) <= high[2]


def test_paper_case_study_percentlist():
    """§2.3.2 case study: feed the 10 recorded percentages through Eq. 2/3.

    The paper reports thresholds mixing floor/round behaviour; we pin the
    literal Eq. 2 (floor) results and check the qualitative claim — the
    threshold tracks the percentage distribution and the high-percentage
    streams (.6299/.6062/.622/.6771...) end up above it.
    """
    seq = [0.3937, 0.5433, 0.5905, 0.6299, 0.6062, 0.5826, 0.622, 0.622, 0.622, 0.6771]
    live = []
    thresholds = []
    for p in seq:
        live.append(p)
        live.sort()
        plist = np.zeros(C.PERCENT_LIST_CAP, np.float32)
        plist[: len(live)] = np.asarray(live, np.float32)
        thr, avg = model.threshold(jnp.asarray(plist), jnp.int32(len(live)))
        thresholds.append(float(thr))
        assert min(live) - 1e-6 <= float(thr) <= max(live) + 1e-6
        np.testing.assert_allclose(float(avg), np.mean(live), rtol=1e-5)
    # thresholds stay in the paper's reported band [0.39, 0.61]
    assert all(0.39 <= t <= 0.61 for t in thresholds)
    final = thresholds[-1]
    above = [p for p in seq if p > final]
    # the clearly-random streams are classified above the final threshold
    assert set([0.6299, 0.6771]) <= set(above)


def test_detect_on_paper_patterns_sorted_rp():
    """§2.2/Fig 5 golden bands: RP(contig) ~= 11%, RP(random) = 100%,
    RP(strided) ~= 45% — we assert the bands, not the exact testbed values,
    because arrival interleavings differ."""
    n = 128
    cases = {
        "contig": (patterns.segmented_contiguous(n, procs=16, seed=5), (0.0, 0.25)),
        "random": (patterns.segmented_random(n, seed=5), (0.98, 1.0)),
        "strided": (patterns.strided(n, procs=16, seed=5), (0.0, 0.6)),
        "mixed": (patterns.mixed(n, seed=5), (0.4, 1.0)),
    }
    streams = [v[0] for v in cases.values()]
    o, s, ln = patterns.pad_batch(streams + [streams[0]] * (C.BATCH - len(streams)), C.NMAX, C.BATCH)
    _, pct, _ = model.detect(jnp.asarray(o), jnp.asarray(s), jnp.asarray(ln))
    pct = np.asarray(pct)
    for i, (name, (_, (lo, hi))) in enumerate(cases.items()):
        assert lo <= pct[i] <= hi, f"{name}: {pct[i]} not in [{lo},{hi}]"
    # ordering claim: random > mixed > contiguous
    assert pct[1] > pct[3] > pct[0]
