"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps stream shapes, lengths, and offset/size contents;
pattern-specific cases pin the paper's qualitative claims (contiguous -> 0,
fully random -> N-1, strided in between).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import constants as C
from compile.kernels import ref
from compile.kernels.random_factor import random_factor
from compile.kernels.seek_cost import seek_cost

from tests import patterns


def _sorted_batch(offsets, sizes, lengths):
    so, ss = ref.sort_stream(jnp.asarray(offsets), jnp.asarray(sizes), jnp.asarray(lengths))
    return so, ss, jnp.asarray(lengths)


def _random_case(rng, batch, nmax):
    offsets = rng.integers(0, 2**24, size=(batch, nmax)).astype(np.int32)
    sizes = rng.integers(1, 4096, size=(batch, nmax)).astype(np.int32)
    lengths = rng.integers(0, nmax + 1, size=(batch,)).astype(np.int32)
    return offsets, sizes, lengths


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nmax=st.sampled_from([8, 32, 128, 512]))
def test_random_factor_matches_ref(seed, nmax):
    rng = np.random.default_rng(seed)
    offsets, sizes, lengths = _random_case(rng, C.BATCH, nmax)
    so, ss, ln = _sorted_batch(offsets, sizes, lengths)
    got = random_factor(so, ss, ln)
    want = ref.random_factor_ref(so, ss, ln)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nmax=st.sampled_from([8, 32, 128, 512]))
def test_seek_cost_matches_ref(seed, nmax):
    rng = np.random.default_rng(seed)
    offsets, sizes, lengths = _random_case(rng, C.BATCH, nmax)
    so, ss, ln = _sorted_batch(offsets, sizes, lengths)
    got = seek_cost(so, ss, ln)
    want = ref.seek_cost_ref(so, ss, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_factor_bounds(seed):
    """0 <= S <= length-1 always (paper: max 127 movements for 128 reqs)."""
    rng = np.random.default_rng(seed)
    offsets, sizes, lengths = _random_case(rng, C.BATCH, 128)
    so, ss, ln = _sorted_batch(offsets, sizes, lengths)
    s = np.asarray(random_factor(so, ss, ln))
    assert (s >= 0).all()
    assert (s <= np.maximum(lengths - 1, 0)).all()


def test_contiguous_stream_has_zero_rf():
    """A perfectly contiguous stream needs no head movement (S = 0), even
    when requests arrive out of order — sorting recovers sequentiality."""
    n = 128
    req = 512
    offs = np.arange(n, dtype=np.int32) * req
    rng = np.random.default_rng(7)
    perm = rng.permutation(n)
    streams = [(offs[perm], np.full(n, req, np.int32))] * C.BATCH
    o, s, ln = patterns.pad_batch(streams, C.NMAX, C.BATCH)
    so, ss, lnj = _sorted_batch(o, s, ln)
    np.testing.assert_array_equal(np.asarray(random_factor(so, ss, lnj)), 0)
    np.testing.assert_allclose(np.asarray(seek_cost(so, ss, lnj)), 0.0)


def test_fully_random_stream_has_max_rf():
    """Sparse random offsets: every adjacent sorted pair is a seek."""
    n = 128
    o_np, s_np = patterns.segmented_random(n, seed=3)
    o, s, ln = patterns.pad_batch([(o_np, s_np)] * C.BATCH, C.NMAX, C.BATCH)
    so, ss, lnj = _sorted_batch(o, s, ln)
    s_out = np.asarray(random_factor(so, ss, lnj))
    # offsets are distinct multiples of req with gaps > req almost surely
    assert (s_out == n - 1).all()


@pytest.mark.parametrize(
    "gen,kwargs,lo,hi",
    [
        (patterns.segmented_contiguous, {"procs": 16}, 0.0, 0.25),
        (patterns.strided, {"procs": 16}, 0.0, 0.6),
        (patterns.segmented_random, {}, 0.95, 1.0),
    ],
)
def test_pattern_random_percentage_bands(gen, kwargs, lo, hi):
    """Qualitative §2.2 claim: contiguous < strided < random randomness."""
    n = 128
    o_np, s_np = gen(n, seed=11, **kwargs)
    o, s, ln = patterns.pad_batch([(o_np, s_np)] * C.BATCH, C.NMAX, C.BATCH)
    so, ss, lnj = _sorted_batch(o, s, ln)
    s_out = np.asarray(random_factor(so, ss, lnj))[0]
    pct = s_out / (n - 1)
    assert lo <= pct <= hi, f"percentage {pct} outside [{lo}, {hi}]"


def test_empty_and_single_request_streams():
    """length 0 and 1 must contribute S = 0 and cost 0 (no adjacent pair)."""
    o = np.zeros((C.BATCH, 16), np.int32)
    s = np.full((C.BATCH, 16), 8, np.int32)
    ln = np.array([0, 1] * (C.BATCH // 2), np.int32)
    so, ss, lnj = _sorted_batch(o, s, ln)
    np.testing.assert_array_equal(np.asarray(random_factor(so, ss, lnj)), 0)
    np.testing.assert_allclose(np.asarray(seek_cost(so, ss, lnj)), 0.0)


def test_seek_cost_piecewise_knee():
    """One short gap and one long gap hit the two seek-model branches."""
    req = 8
    # stream: [0, req) then a gap landing exactly on the knee, then far away
    offs = np.array([0, req + C.SEEK_KNEE_SECTORS, 10**7], np.int32)
    sizes = np.full(3, req, np.int32)
    streams = [(offs, sizes)] * C.BATCH
    o, s, ln = patterns.pad_batch(streams, 16, C.BATCH)
    so, ss, lnj = _sorted_batch(o, s, ln)
    got = float(np.asarray(seek_cost(so, ss, lnj))[0])
    # first pair: |gap - size| = knee -> short branch (boundary inclusive)
    short = C.SEEK_SHORT_BASE_US + C.SEEK_SHORT_US_PER_SECTOR * C.SEEK_KNEE_SECTORS
    d2 = 10**7 - (req + C.SEEK_KNEE_SECTORS) - req
    long = C.SEEK_LONG_BASE_US + C.SEEK_LONG_US_PER_SECTOR * min(d2, C.SEEK_CAP_SECTORS)
    np.testing.assert_allclose(got, short + long, rtol=1e-6)
